"""Multi-host graph serving: wire codec exactness (round-trip, corrupt /
truncated / cross-version frames), remote Select/Build bitwise equality
against the in-process pipeline over both transports (loopback and a real
TCP socket — including a separate graph-host PROCESS), per-ticket timeout
+ bounded retry semantics, and the kill-a-graph-host degradation path."""
import os
import socket
import subprocess
import sys
import time

import numpy as np
import pytest

import repro
from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.distributed import wire
from repro.distributed.graph_host import GraphHostService
from repro.distributed.rpc import (GraphHostServer, HostPool,
                                   InProcTransport, RemoteCallError,
                                   RPCTimeout, SocketTransport,
                                   TransportError)
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph
from repro.store import StorePolicy

N = 16
C = 4
SCALE = 0.004            # ~357 vertices
SEED = 1
TARGETS = np.arange(12)


@pytest.fixture(scope="module")
def graph():
    return get_graph("flickr", scale=SCALE, seed=SEED)


def _cfg(kind, graph):
    return GNNConfig(kind=kind, n_layers=2, receptive_field=N,
                     f_in=graph.feature_dim)


def _subproc_env():
    env = dict(os.environ)
    src = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    return env


def _spawn_graph_host(extra_args=()):
    """Launch a graph host subprocess serving the SAME synthetic graph
    (dataset+scale+seed pin it bitwise) and return (proc, endpoint)."""
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.distributed.graph_host",
         "--dataset", "flickr", "--scale", str(SCALE),
         "--seed", str(SEED), "--port", "0", "--num-threads", "2",
         *extra_args],
        env=_subproc_env(), stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True)
    t0 = time.time()
    while True:
        line = proc.stdout.readline()
        if line.startswith("GRAPH_HOST_LISTENING"):
            _, host, port = line.split()
            return proc, f"{host}:{port}"
        if proc.poll() is not None or time.time() - t0 > 60:
            proc.kill()
            raise RuntimeError(f"graph host failed to start: {line!r}")


class TestWireCodec:
    def test_roundtrip_every_dtype_and_shape(self):
        rng = np.random.default_rng(0)
        arrays = [
            np.asarray(7, np.int32),                       # 0-d scalar
            np.empty((0, 3), np.float32),                  # empty
            rng.integers(-9, 9, (5,), endpoint=True).astype(np.int8),
            rng.integers(0, 2**31, (3, 4)).astype(np.int64),
            rng.standard_normal((2, 3, 4)).astype(np.float32),
            rng.standard_normal((8,)).astype(np.float64),
            np.array([True, False, True]),
        ]
        tree = {"arrays": arrays, "s": "x", "i": 3, "f": 0.5,
                "none": None, "flag": True, "nested": {"a": arrays[4]},
                "blob": b"\x00\xffraw"}
        out = wire.decode(wire.encode(tree))
        for a, b in zip(arrays, out["arrays"]):
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_array_equal(a, b)
        assert out["s"] == "x" and out["i"] == 3 and out["f"] == 0.5
        assert out["none"] is None and out["flag"] is True
        assert out["blob"] == b"\x00\xffraw"
        np.testing.assert_array_equal(out["nested"]["a"], arrays[4])

    def test_batchplan_roundtrip_exact(self, graph):
        """Full BatchPlan — node lists, frontiers, rows, device payload
        with the store's generation pin — survives the wire bitwise."""
        cfg = _cfg("gcn", graph)
        with DecoupledEngine(graph, cfg, config=ServingConfig(
                batch_size=C,
                store=StorePolicy(features="resident",
                                  nbr_cache="lru"))) as eng:
            plan = eng.plan(TARGETS[:C])
            out = wire.plan_from_wire(
                wire.decode(wire.encode(wire.plan_to_wire(plan))))
            np.testing.assert_array_equal(out.targets, plan.targets)
            assert len(out.node_lists) == len(plan.node_lists)
            for a, b in zip(plan.node_lists, out.node_lists):
                assert a.dtype == b.dtype
                np.testing.assert_array_equal(a, b)
            for t, fr in plan.frontiers.items():
                np.testing.assert_array_equal(out.frontiers[t], fr)
            for a, b in zip(plan.rows, out.rows):
                for f in ("adj", "adj_mean", "mask", "edge_src",
                          "edge_dst", "edge_w", "self_w", "edge_w_mean"):
                    ax, bx = getattr(a, f), getattr(b, f)
                    assert ax.dtype == bx.dtype
                    np.testing.assert_array_equal(ax, bx)
            assert set(out.device) == set(plan.device)
            for k in plan.device:
                a, b = np.asarray(plan.device[k]), out.device[k]
                assert a.dtype == b.dtype and a.shape == b.shape
                np.testing.assert_array_equal(a, b)
            # generation pin survives the hop (resident store)
            assert int(out.device["store_gen"]) \
                == int(plan.device["store_gen"])
            eng.run_device(plan)     # consume the pinned generation

    def test_sharded_payload_roundtrip_exact(self, graph):
        cfg = _cfg("gcn", graph)
        with DecoupledEngine(graph, cfg, config=ServingConfig(
                batch_size=C,
                store=StorePolicy(features="sharded",
                                  num_shards=2))) as eng:
            plan = eng.plan(TARGETS[:C])
            out = wire.decode(wire.encode(
                {k: np.asarray(v) for k, v in plan.device.items()}))
            for k, v in plan.device.items():
                a = np.asarray(v)
                assert a.dtype == out[k].dtype and a.shape == out[k].shape
                np.testing.assert_array_equal(a, out[k])
            assert int(out["shard_gen"]) == int(plan.device["shard_gen"])
            eng.run_device(plan)

    def test_truncated_frame_rejected(self):
        frame = wire.encode({"a": np.arange(100)})
        with pytest.raises(wire.WireFormatError, match="truncated"):
            wire.decode(frame[:-10])
        with pytest.raises(wire.WireFormatError, match="header"):
            wire.decode(frame[:6])

    def test_corrupt_magic_rejected(self):
        frame = bytearray(wire.encode({"a": 1}))
        frame[:4] = b"EVIL"
        with pytest.raises(wire.WireFormatError, match="magic"):
            wire.decode(bytes(frame))

    def test_version_mismatch_actionable(self):
        frame = bytearray(wire.encode({"a": 1}))
        frame[4:6] = (99).to_bytes(2, "big")
        with pytest.raises(wire.WireVersionError,
                           match="v99.*v1|upgrade"):
            wire.decode(bytes(frame))

    def test_unencodable_value_rejected(self):
        with pytest.raises(wire.WireFormatError, match="cannot encode"):
            wire.encode({"bad": object()})


class TestRemoteBitwise:
    @pytest.mark.parametrize("kind", ["gcn", "sage", "gat"])
    def test_inproc_loopback_matches_local(self, graph, kind):
        """Remote Select/Build over the loopback transport (full codec
        both legs) is bitwise-identical to the in-process pipeline."""
        cfg = _cfg(kind, graph)
        with DecoupledEngine(graph, cfg, config=ServingConfig(
                batch_size=C, num_threads=2)) as local:
            ref = local.infer(TARGETS).embeddings
            with DecoupledEngine(
                    graph, cfg, params=local.params,
                    config=ServingConfig(batch_size=C, num_threads=2,
                                         transport="inproc")) as remote:
                got = remote.infer(TARGETS).embeddings
                np.testing.assert_array_equal(got, ref)
                s = remote.scheduler.stats
                assert s.rpc_calls == len(TARGETS) // C
                assert s.rpc_bytes_out > 0 and s.rpc_bytes_in > 0
                assert s.rpc_errors == 0
                rpc = s.summary()["rpc"]
                assert rpc["calls"] == s.rpc_calls

    def test_socket_transport_in_thread_matches_local(self, graph):
        """SocketTransport against a threaded server in this process:
        real TCP framing, bitwise-equal outputs, rpc.* counters."""
        cfg = _cfg("gcn", graph)
        svc = GraphHostService(graph, num_threads=2)
        server = GraphHostServer(svc)
        try:
            sc = ServingConfig(batch_size=C, num_threads=2,
                               transport="socket",
                               endpoints=(server.endpoint,),
                               rpc_timeout_s=60.0)
            with DecoupledEngine(graph, cfg, config=ServingConfig(
                    batch_size=C, num_threads=2)) as local:
                ref = local.infer(TARGETS).embeddings
                with DecoupledEngine(graph, cfg, params=local.params,
                                     config=sc) as remote:
                    got = remote.infer(TARGETS).embeddings
                    np.testing.assert_array_equal(got, ref)
                    rep = remote.store_report()
                    hosts = rep["graph_hosts"]
                    assert hosts[0]["healthy"]
                    assert hosts[0]["report"]["requests"] >= 3
                    # remote invalidation drops the graph host's caches
                    assert remote.invalidate(TARGETS[:2]) > 0
        finally:
            server.close()

    def test_two_process_socket_matches_local(self, graph):
        """The real thing: a graph host in a SEPARATE process serves
        Select/Build over TCP; outputs match in-process bitwise."""
        cfg = _cfg("gcn", graph)
        proc, endpoint = _spawn_graph_host()
        try:
            with DecoupledEngine(graph, cfg, config=ServingConfig(
                    batch_size=C, num_threads=2)) as local:
                ref = local.infer(TARGETS).embeddings
                with DecoupledEngine(
                        graph, cfg, params=local.params,
                        config=ServingConfig(
                            batch_size=C, num_threads=2,
                            transport="socket",
                            endpoints=(endpoint,),
                            rpc_timeout_s=120.0)) as remote:
                    got = remote.infer(TARGETS).embeddings
                    np.testing.assert_array_equal(got, ref)
        finally:
            proc.kill()
            proc.wait(timeout=10)


class TestFailureIsolation:
    def test_kill_graph_host_errors_only_inflight_tickets(self, graph):
        """Two graph hosts, no retries: killing one mid-stream errors
        the tickets in flight on it (TransportError), the pool marks it
        down, and every later ticket lands on the survivor — the
        pipeline degrades instead of wedging."""
        cfg = _cfg("gcn", graph)
        proc_a, ep_a = _spawn_graph_host()
        proc_b, ep_b = _spawn_graph_host()
        eng = DecoupledEngine(graph, cfg, config=ServingConfig(
            batch_size=C, num_threads=2, transport="socket",
            endpoints=(ep_a, ep_b), rpc_retries=0, rpc_timeout_s=120.0,
            rpc_concurrency=1))
        try:
            # warm both hosts (round-robin touches each)
            for i in range(2):
                eng.submit_chunk(TARGETS[:C]).result(timeout=120)
            proc_a.kill()
            proc_a.wait(timeout=10)
            tickets = [eng.submit_chunk(TARGETS[:C]) for _ in range(6)]
            outcomes = []
            for t in tickets:
                try:
                    t.result(timeout=120)
                    outcomes.append("ok")
                except TransportError:
                    outcomes.append("err")
            # the dead host fails SOME tickets (those routed to it before
            # quarantine kicks in) but never all: the survivor serves the
            # rest, and the scheduler stays alive for new submissions
            assert "err" in outcomes and "ok" in outcomes
            assert eng.scheduler.stats.rpc_errors >= 1
            after = eng.submit_chunk(TARGETS[:C]).result(timeout=120)
            assert np.isfinite(np.asarray(after)).all()
            healthy = {h["endpoint"]: h["healthy"]
                       for h in eng._host_pool.report()}
            assert healthy[ep_b]
        finally:
            eng.close()
            for p in (proc_a, proc_b):
                p.kill()
                p.wait(timeout=10)

    def test_retry_reroutes_to_healthy_host(self, graph):
        """With retries enabled, a dead host costs a retry, not a
        ticket: calls transparently fail over to the live host."""
        cfg = _cfg("gcn", graph)
        proc, endpoint = _spawn_graph_host()
        # a dead endpoint: bind+close to get a port nothing listens on
        s = socket.socket()
        s.bind(("127.0.0.1", 0))
        dead = f"127.0.0.1:{s.getsockname()[1]}"
        s.close()
        eng = DecoupledEngine(graph, cfg, config=ServingConfig(
            batch_size=C, num_threads=2, transport="socket",
            endpoints=(dead, endpoint), rpc_retries=1,
            rpc_timeout_s=120.0))
        try:
            out = eng.infer(TARGETS).embeddings
            assert np.isfinite(out).all()
            assert eng.scheduler.stats.rpc_errors == 0
        finally:
            eng.close()
            proc.kill()
            proc.wait(timeout=10)

    def test_per_call_timeout_raises_rpc_timeout(self):
        """A hung handler trips the per-call deadline as RPCTimeout (a
        TransportError — retryable), and the pool quarantines the
        host."""
        class Stuck:
            def handle(self, request):
                time.sleep(2.0)
                return {"ok": True, "result": None, "remote_s": 2.0}

        server = GraphHostServer(Stuck())
        pool = HostPool([SocketTransport(server.endpoint)],
                        timeout=0.2, retries=0)
        try:
            with pytest.raises(RPCTimeout, match="within 0.2s"):
                pool.call("select_build", {"x": 1})
            assert not pool.report()[0]["healthy"]
        finally:
            pool.close()
            server.close()

    def test_remote_application_error_not_retried(self, graph):
        """A handler exception is a RemoteCallError carrying the remote
        type/message — deterministic, so the pool must NOT burn retries
        on other hosts."""
        svc = GraphHostService(graph, num_threads=1)
        calls = []

        class Counting(InProcTransport):
            def call(self, method, payload, timeout=None):
                calls.append(method)
                return super().call(method, payload, timeout)

        pool = HostPool([Counting(svc), Counting(svc)], retries=2)
        with pytest.raises(RemoteCallError, match="KeyError|missing"):
            pool.call("select_build", {"targets": np.arange(2)})
        assert len(calls) == 1          # no retry
        with pytest.raises(RemoteCallError, match="unknown method"):
            pool.call("no_such_method", None)
        svc.close()

    def test_affine_routing_pins_targets_to_hosts(self, graph):
        svc_a = GraphHostService(graph, num_threads=1)
        svc_b = GraphHostService(graph, num_threads=1)
        pool = HostPool([InProcTransport(svc_a), InProcTransport(svc_b)],
                        routing="affine")
        payload = {"targets": np.asarray([2], np.int64), "n": N,
                   "alpha": 0.15, "eps": 1e-4, "e_pad": 64}
        for _ in range(3):              # affinity 2 -> host index 0
            pool.call("select_build", payload, affinity=2)
        assert svc_a.requests == 3 and svc_b.requests == 0
        for _ in range(2):              # affinity 5 -> host index 1
            pool.call("select_build", payload, affinity=5)
        assert svc_b.requests == 2
        svc_a.close()
        svc_b.close()
