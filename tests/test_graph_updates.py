"""Graph-update streaming (ROADMAP item): CSRGraph.apply_edge_updates
mutates the CSR in place and drives DecoupledEngine.invalidate through the
registered listener, so post-update inference matches a fresh engine on
the mutated graph."""
import numpy as np
import pytest

from repro.core.engine import DecoupledEngine
from repro.gnn.model import GNNConfig
from repro.graphs.csr import from_edge_list
from repro.store import StorePolicy


def make_graph(v=120, seed=7, extra=3, f=12):
    rng = np.random.default_rng(seed)
    src = np.arange(1, v)
    dst = rng.integers(0, np.maximum(src, 1))
    es = rng.integers(0, v, size=v * extra)
    ed = rng.integers(0, v, size=v * extra)
    feats = rng.standard_normal((v, f)).astype(np.float32)
    return from_edge_list(np.concatenate([src, es]),
                          np.concatenate([dst, ed]), v, feats)


class TestApplyEdgeUpdates:
    def test_insert_and_delete_update_structure(self):
        g = make_graph()
        # pick a definitely-absent edge and a definitely-present one
        u = int(np.argmin(g.degrees))
        w = next(int(x) for x in np.argsort(-g.degrees)
                 if x != u and x not in g.neighbors(u))
        present = (w, int(g.neighbors(w)[0]))
        deg_before = g.degrees.copy()
        affected = g.apply_edge_updates(insert=[(u, w)], delete=[present])
        g.validate()
        assert w in g.neighbors(u) and u in g.neighbors(w)   # symmetrized
        assert present[1] not in g.neighbors(w)
        assert set(affected) == {u, w, present[0], present[1]}
        assert g.degrees[u] == deg_before[u] + 1

    def test_self_loops_and_duplicates_ignored(self):
        g = make_graph()
        e_before = g.num_edges
        existing = (0, int(g.neighbors(0)[0]))
        g.apply_edge_updates(insert=[(5, 5), existing])
        assert g.num_edges == e_before            # both were no-ops

    def test_out_of_range_vertex_rejected(self):
        g = make_graph()
        with pytest.raises(ValueError, match="outside"):
            g.apply_edge_updates(insert=[(0, g.num_vertices + 3)])

    def test_listener_notified_and_unregistered_on_close(self):
        g = make_graph()
        cfg = GNNConfig(kind="gcn", n_layers=2, receptive_field=16,
                        f_in=g.feature_dim)
        eng = DecoupledEngine(g, cfg, batch_size=4,
                              store=StorePolicy(nbr_cache="lru",
                                                nbr_capacity=64))
        assert eng.invalidate in g._listeners
        eng.infer(np.arange(4), overlap=False)    # populate nbr cache
        g.apply_edge_updates(insert=[(0, 1)])
        # targets 0..3 all contain themselves -> their entries dropped
        assert eng.nbr_cache.stats()["invalidations"] > 0
        eng.close()
        assert eng.invalidate not in g._listeners


class TestPostUpdateInference:
    @pytest.mark.parametrize("nbr_cache", ["none", "lru"])
    def test_matches_fresh_engine_on_mutated_graph(self, nbr_cache):
        g = make_graph()
        cfg = GNNConfig(kind="sage", n_layers=2, receptive_field=16,
                        f_in=g.feature_dim)
        pol = StorePolicy() if nbr_cache == "none" else \
            StorePolicy(nbr_cache="lru", nbr_capacity=64)
        eng = DecoupledEngine(g, cfg, batch_size=4, store=pol)
        targets = np.arange(4, dtype=np.int64)
        eng.infer(targets, overlap=False)          # warm caches pre-update
        # edge updates incident to every tested target: their cached
        # neighborhoods contain themselves, so invalidation must hit
        g.apply_edge_updates(insert=[(0, 50), (1, 51)],
                             delete=[(2, int(g.neighbors(2)[0]))])
        post = eng.infer(targets, overlap=False).embeddings
        fresh = DecoupledEngine(g, cfg, params=eng.params, batch_size=4)
        want = fresh.infer(targets, overlap=False).embeddings
        np.testing.assert_array_equal(post, want)
        eng.close()
        fresh.close()

    def test_resident_store_rows_refresh_on_feature_change(self):
        g = make_graph()
        cfg = GNNConfig(kind="gcn", n_layers=2, receptive_field=16,
                        f_in=g.feature_dim)
        eng = DecoupledEngine(g, cfg, batch_size=4,
                              store=StorePolicy(features="resident"))
        targets = np.arange(4, dtype=np.int64)
        eng.infer(targets, overlap=False)
        g.features[0] += 1.0                       # feature mutation
        g.apply_edge_updates(insert=[(0, 60)])     # structural + notify
        post = eng.infer(targets, overlap=False).embeddings
        fresh = DecoupledEngine(g, cfg, params=eng.params, batch_size=4)
        want = fresh.infer(targets, overlap=False).embeddings
        np.testing.assert_allclose(post, want, rtol=1e-6, atol=1e-7)
        eng.close()
        fresh.close()


class TestExactFrontierInvalidation:
    """invalidate() is exact: the cache holds each push's FULL touched
    set, so an update at a vertex the push reached but that fell below
    the top-N cutoff still drops the entry (the pre-frontier
    approximation missed exactly this case)."""

    def _engine_with_frontier_gap(self):
        """Engine + (target, frontier-only vertex): a vertex in the
        push's touched set but NOT in the truncated top-N selection."""
        from repro.core.ini import select_important
        g = make_graph(v=200, seed=3)
        n = 8                                      # tight cutoff
        cfg = GNNConfig(kind="gcn", n_layers=2, receptive_field=n,
                        f_in=g.feature_dim)
        eng = DecoupledEngine(g, cfg, batch_size=4,
                              store=StorePolicy(nbr_cache="lru",
                                                nbr_capacity=64))
        for t in range(40):
            sel, frontier = select_important(g, t, n, cfg.ppr_alpha,
                                             cfg.ppr_eps,
                                             with_frontier=True)
            below = np.setdiff1d(frontier, sel)
            if len(below):
                return eng, g, t, int(below[0]), sel
        raise AssertionError("no target with touched set > top-N")

    def test_update_below_cutoff_drops_entry(self):
        eng, g, t, below_cutoff, sel = self._engine_with_frontier_gap()
        targets = eng.pad_targets(np.array([t]))
        eng.infer(targets, overlap=False)          # cache the push
        assert below_cutoff not in sel             # the gap is real
        dropped = eng.invalidate([below_cutoff])
        assert dropped >= 1                        # exact: still detected
        misses0 = eng.nbr_cache.misses
        eng.infer(targets, overlap=False)
        assert eng.nbr_cache.misses > misses0      # recomputed
        eng.close()

    def test_put_without_frontier_falls_back_to_selection(self):
        from repro.store import NeighborhoodCache, nbr_key
        c = NeighborhoodCache(capacity=4)
        k = nbr_key(1, 8, 0.15, 1e-4)
        c.put(k, np.array([1, 5]))                 # no frontier attached
        assert c.invalidate([9]) == 0              # 9 not in selection
        assert c.invalidate([5]) == 1              # selection still scanned

    def test_frontier_scan_preferred_over_selection(self):
        from repro.store import NeighborhoodCache, nbr_key
        c = NeighborhoodCache(capacity=4)
        k = nbr_key(1, 8, 0.15, 1e-4)
        c.put(k, np.array([1, 5]), frontier=np.array([1, 5, 9]))
        assert c.invalidate([9]) == 1              # frontier-only vertex
