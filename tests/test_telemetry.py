"""Telemetry plane: registry semantics, lossless window/cluster merge,
Prometheus exposition + validator, cluster scrape over the RPC pool,
SLO burn-rate math, watchdog detections, zero-cost-when-off bitwise
equality, report schema v4 coverage, and the trajectory regression
gate."""
import json
import urllib.request

import numpy as np
import pytest

from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.core.report_schema import SCHEMA, SCHEMA_VERSION
from repro.distributed.graph_host import GraphHostService
from repro.distributed.rpc import (HostPool, InProcTransport,
                                   TransportError)
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph
from repro.obs import (EventRing, LogHistogram, MetricsHTTPServer,
                       MetricsRegistry, SLObjective, SLOTracker,
                       Telemetry, TelemetryConfig, Watchdog,
                       WindowedHistogram, inject_labels,
                       merge_hist_dicts, merge_wire, render_wire,
                       series_count, validate_exposition)
from repro.obs.regress import check_trajectory, main as regress_main

N = 16
C = 4
SCALE = 0.004
SEED = 1


@pytest.fixture(scope="module")
def graph():
    return get_graph("flickr", scale=SCALE, seed=SEED)


def _cfg(graph):
    return GNNConfig(kind="gcn", n_layers=2, receptive_field=N,
                     f_in=graph.feature_dim)


class _Clock:
    """Deterministic manual clock for window-rotation tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


class TestRegistry:
    def test_counter_and_gauge_semantics(self):
        reg = MetricsRegistry("h")
        c = reg.counter("repro_x_total", help="x")
        c.inc()
        c.inc(3)
        assert c.value == 4
        with pytest.raises(ValueError):
            c.inc(-1)
        g = reg.gauge("repro_depth")
        g.set(7)
        g.add(-2)
        assert g.value == 5
        # same name + labels -> same object; new labels -> new series
        assert reg.counter("repro_x_total") is c
        c2 = reg.counter("repro_x_total", shard="1")
        assert c2 is not c
        assert series_count(reg.collect()) == 3

    def test_type_conflict_raises(self):
        reg = MetricsRegistry("h")
        reg.counter("repro_x_total")
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("repro_x_total")

    def test_callback_series_and_dead_callback(self):
        reg = MetricsRegistry("h")
        src = {"hits": 0}
        reg.counter_fn("repro_hits_total", lambda: src["hits"])
        src["hits"] = 9
        wire = reg.collect()
        row = wire["families"]["repro_hits_total"]["series"][0]
        assert row["value"] == 9.0

        def dead():
            raise RuntimeError("source gone")

        reg.gauge_fn("repro_dead", dead)
        wire = reg.collect()                 # scrape must survive
        assert wire["families"]["repro_dead"]["series"] == []

    def test_window_merge_equals_whole_run(self):
        """Merging every retained window + current must be bitwise the
        histogram of all samples (lossless window merge)."""
        clk = _Clock()
        wh = WindowedHistogram(window_s=1.0, windows=8, clock=clk)
        ref = LogHistogram()
        rng = np.random.default_rng(0)
        for i in range(400):
            v = float(rng.gamma(2.0, 0.005))
            wh.record(v)
            ref.record(v)
            if i % 60 == 59:
                clk.advance(1.1)             # rotate a window
        merged = wh.merged()
        assert merged.count == ref.count == 400
        assert merged.to_dict() == ref.to_dict()

    def test_idle_gap_produces_empty_windows(self):
        clk = _Clock()
        wh = WindowedHistogram(window_s=1.0, windows=4, clock=clk)
        wh.record(0.01)
        clk.advance(3.5)                     # 3 whole windows idle
        wh.record(0.02)
        assert wh.window_counts().count(0) >= 2
        assert wh.merged().count == 2

    def test_merge_hist_dicts_lossless(self):
        a, b = LogHistogram(), LogHistogram()
        for v in (0.001, 0.02, 0.3):
            a.record(v)
        for v in (0.004, 4.0):
            b.record(v)
        ref = LogHistogram()
        ref.merge(a)
        ref.merge(b)
        # survive a JSON round trip (string bucket keys), like the RPC
        ad = json.loads(json.dumps(a.to_dict()))
        merged = merge_hist_dicts(ad, b.to_dict())
        assert merged["count"] == 5
        assert merged["counts"] == \
            {int(k): v for k, v in ref.to_dict()["counts"].items()}
        assert merged["p99"] == ref.to_dict()["p99"]


class TestWireMerge:
    def _reg(self, host, n_hist, n_count):
        reg = MetricsRegistry(host)
        wh = reg.whist("repro_batch_seconds")
        for i in range(n_hist):
            wh.record(0.001 * (i + 1))
        reg.counter("repro_batches_total").inc(n_count)
        return reg

    def test_two_host_merge_is_sum(self):
        a = self._reg("host-a", 4, 4)
        b = self._reg("host-b", 2, 10)
        m = merge_wire([a.collect(), b.collect()])
        assert m["hosts"] == ["host-a", "host-b"]
        fam = m["families"]["repro_batch_seconds"]["series"][0]
        assert fam["total"]["count"] == 6          # 4 + 2, lossless
        cnt = m["families"]["repro_batches_total"]["series"][0]
        assert cnt["value"] == 14.0
        # merged exposition still validates
        assert validate_exposition(render_wire(m)) == []

    def test_merge_type_conflict_raises(self):
        a = MetricsRegistry("a")
        a.counter("repro_x_total").inc()
        b = MetricsRegistry("b")
        b.gauge("repro_x_total").set(1)
        with pytest.raises(ValueError, match="one host"):
            merge_wire([a.collect(), b.collect()])

    def test_inject_labels_keeps_series_distinct(self):
        a = self._reg("a", 1, 1)
        b = self._reg("b", 1, 1)
        m = merge_wire([inject_labels(a.collect(), model="m0"),
                        inject_labels(b.collect(), model="m1")])
        fam = m["families"]["repro_batches_total"]
        assert len(fam["series"]) == 2           # distinct by model=


class TestExposition:
    def _wire(self):
        reg = MetricsRegistry("h")
        reg.counter("repro_req_total", help='say "hi"\nok',
                    model="gcn").inc(3)
        reg.gauge("repro_backlog").set(2.5)
        wh = reg.whist("repro_lat_seconds", stage="build")
        for v in (0.001, 0.01, 0.1):
            wh.record(v)
        return reg.collect()

    def test_render_validates_clean(self):
        text = render_wire(self._wire())
        assert validate_exposition(text) == []
        assert 'model="gcn"' in text
        assert "# TYPE repro_lat_seconds histogram" in text
        # +Inf bucket equals _count
        assert 'le="+Inf"' in text

    def test_validator_rejects_malformed(self):
        bad = "\n".join([
            "# TYPE repro_a counter",
            "repro_a 1",
            "repro_a 2",                     # duplicate series
            "repro-b 3",                     # bad metric name
            "repro_c{le=\"0.1\"} nope",      # bad value
        ])
        problems = validate_exposition(bad)
        assert len(problems) >= 3

    def test_validator_rejects_nonmonotone_buckets(self):
        bad = "\n".join([
            "# TYPE repro_h histogram",
            'repro_h_bucket{le="0.1"} 5',
            'repro_h_bucket{le="0.2"} 3',    # cumulative decreased
            'repro_h_bucket{le="+Inf"} 5',
            "repro_h_count 5",
            "repro_h_sum 0.5",
        ])
        assert any("non-decreasing" in p or "cumulative" in p
                   for p in validate_exposition(bad))

    def test_http_endpoint(self):
        wire = self._wire()
        srv = MetricsHTTPServer(lambda: render_wire(wire))
        try:
            with urllib.request.urlopen(srv.url, timeout=5) as r:
                assert r.status == 200
                body = r.read().decode()
            assert validate_exposition(body) == []
            health = srv.url.rsplit("/", 1)[0] + "/healthz"
            with urllib.request.urlopen(health, timeout=5) as r:
                assert r.status == 200
        finally:
            srv.close()


class TestClusterScrape:
    def test_two_graph_hosts_scrape_merge(self, graph):
        """metrics() over the pool: per-host registries merge into one
        cluster view whose counts are the per-host sums."""
        tc = TelemetryConfig(window_s=60.0)
        svc_a = GraphHostService(graph, num_threads=1, telemetry=tc)
        svc_b = GraphHostService(graph, num_threads=1, telemetry=tc)
        pool = HostPool([InProcTransport(svc_a, owns_service=True),
                         InProcTransport(svc_b, owns_service=True)])
        try:
            for i in range(6):
                payload = {"targets": np.asarray([i], np.int64),
                           "n": N, "alpha": 0.15, "eps": 1e-4,
                           "e_pad": 64}
                pool.call("select_build", payload, affinity=i)
            wires = pool.broadcast("metrics", None)
            assert len(wires) == 2
            per_host = [w["families"]["repro_host_requests_total"]
                        ["series"][0]["value"] for w in wires]
            merged = merge_wire(wires)
            assert len(merged["hosts"]) == 2
            fam = merged["families"]["repro_host_requests_total"]
            assert fam["series"][0]["value"] == sum(per_host) == 6
            sel = merged["families"]["repro_host_select_seconds"]
            assert sel["series"][0]["total"]["count"] == 6
            assert validate_exposition(render_wire(merged)) == []
        finally:
            pool.close()

    def test_metrics_method_off_returns_empty(self, graph):
        svc = GraphHostService(graph, num_threads=1)
        assert svc.metrics()["families"] == {}


class TestSLO:
    def _tracker(self, slo, **kw):
        cfg = TelemetryConfig(window_s=60.0, slos=(slo,),
                              min_samples=kw.pop("min_samples", 8),
                              **kw)
        reg = MetricsRegistry("h")
        events = EventRing()
        return SLOTracker(cfg, reg, events), reg, events

    def test_latency_burn_rate_math(self):
        o = SLObjective(name="p999-50ms", threshold_s=0.050,
                        target=0.999)
        tracker, reg, events = self._tracker(o)
        wh = reg.whist("repro_batch_seconds")
        for _ in range(99):
            wh.record(0.001)
        wh.record(0.500)                     # 1% above threshold
        rows = tracker.evaluate()
        (row,) = rows
        # bad fraction 0.01 over budget 0.001 => burn 10x: above the
        # slow bar (6) but below the fast bar (14.4)
        assert row["burn"]["fast"]["short"] == pytest.approx(10.0)
        assert row["status"] == "breach"
        assert events.snapshot(kind="slo_breach")[0]["severity"] == \
            "warn"

    def test_ok_then_fast_breach(self):
        o = SLObjective(name="lat", threshold_s=0.050, target=0.999)
        tracker, reg, events = self._tracker(o)
        wh = reg.whist("repro_batch_seconds")
        for _ in range(200):
            wh.record(0.001)
        assert tracker.evaluate()[0]["status"] == "ok"
        for _ in range(20):                  # 10% bad -> burn 100x
            wh.record(0.500)
        row = tracker.evaluate()[0]
        assert row["status"] == "breach"
        assert row["burn"]["fast"]["short"] > 14.4
        assert events.snapshot(kind="slo_breach")[-1]["severity"] == \
            "crit"

    def test_min_samples_gate(self):
        o = SLObjective(name="lat", threshold_s=0.050, target=0.999)
        tracker, reg, _ = self._tracker(o, min_samples=64)
        wh = reg.whist("repro_batch_seconds")
        for _ in range(4):
            wh.record(1.0)                   # all bad, but tiny n
        assert tracker.evaluate()[0]["status"] == "ok"

    def test_error_rate_objective(self):
        o = SLObjective(name="errs", kind="error_rate", target=0.99)
        tracker, reg, _ = self._tracker(o)
        good = reg.counter("repro_batches_total")
        bad = reg.counter("repro_batch_errors_total")
        good.inc(100)
        tracker.evaluate()                   # set marks
        good.inc(100)
        bad.inc(50)                          # 50% errors since last eval
        row = tracker.evaluate()[0]
        assert row["status"] == "breach"

    def test_missing_metric_is_no_data(self):
        o = SLObjective(name="ghost", metric="repro_nope_seconds")
        tracker, _, _ = self._tracker(o)
        assert tracker.evaluate()[0]["status"] == "no_data"


class TestWatchdog:
    def _wd(self, **kw):
        cfg = TelemetryConfig(window_s=60.0,
                              min_samples=kw.pop("min_samples", 8),
                              **kw)
        reg = MetricsRegistry("h")
        events = EventRing()
        return Watchdog(cfg, reg, events), reg, events

    def test_p99_drift_fires_within_one_window(self):
        wd, reg, events = self._wd()
        wh = reg.whist("repro_batch_seconds")
        for _ in range(3):                   # healthy baseline windows
            for _ in range(32):
                wh.record(0.002)
            wh.rotate()
        assert wd.check()["fired"] == {}
        for _ in range(32):                  # 10x p99 step
            wh.record(0.020)
        wh.rotate()                          # the step's window closes
        summary = wd.check()
        assert summary["fired"].get("p99_regression") == 1
        ev = events.snapshot(kind="p99_regression")[0]
        assert ev["data"]["factor"] >= 9.0
        # debounced: the same episode fires exactly once
        wd.check()
        assert wd.summary()["fired"]["p99_regression"] == 1

    def test_p99_drift_ignores_thin_windows(self):
        wd, reg, _ = self._wd(min_samples=16)
        wh = reg.whist("repro_batch_seconds")
        for _ in range(3):
            for _ in range(4):               # < min_samples per window
                wh.record(0.002)
            wh.rotate()
        wh.record(1.0)
        wh.rotate()
        assert wd.check()["fired"] == {}

    def test_cache_hit_collapse(self):
        wd, reg, events = self._wd()
        hits = reg.counter("repro_nbr_cache_hits_total")
        misses = reg.counter("repro_nbr_cache_misses_total")
        hits.inc(90)
        misses.inc(10)                       # lifetime 90%
        wd.check()                           # set marks
        misses.inc(100)                      # window rate ~0%
        assert wd.check()["fired"].get("cache_hit_collapse") == 1
        assert events.snapshot(kind="cache_hit_collapse")

    def test_backlog_growth(self):
        wd, reg, _ = self._wd(backlog_growth_checks=3)
        g = reg.gauge("repro_refresh_backlog")
        for level in (1, 2, 3):
            g.set(level)
            wd.check()
        assert wd.summary()["fired"] == {}   # needs checks+1 points
        g.set(4)
        wd.check()
        assert wd.summary()["fired"]["backlog_growth"] == 1
        g.set(1)                             # recovery re-arms
        wd.check()
        assert wd.summary()["active"] == []

    def test_quarantine_event_from_host_pool(self, graph):
        """HostPool fires on_quarantine once per episode; the engine
        wires it into the event ring + a counter."""
        svc = GraphHostService(graph, num_threads=1)

        class Flaky(InProcTransport):
            def call(self, method, payload, timeout=None):
                if method == "select_build":
                    raise TransportError("boom")
                return super().call(method, payload, timeout)

        seen = []
        pool = HostPool([Flaky(svc), InProcTransport(
            svc, owns_service=True)], retries=1,
            on_quarantine=lambda ep: seen.append(ep))
        try:
            payload = {"targets": np.asarray([1], np.int64), "n": N,
                       "alpha": 0.15, "eps": 1e-4, "e_pad": 64}
            for i in range(4):
                pool.call("select_build", payload, affinity=0)
            assert len(seen) == 1            # one episode, one event
        finally:
            pool.close()


class TestTelemetryHub:
    def test_observe_batch_and_report(self):
        t = Telemetry(TelemetryConfig(window_s=60.0), host="client")
        try:
            for i in range(10):
                t.observe_batch(0.004, {"select": 0.001,
                                        "build": 0.002},
                                error=(i == 9))
            rep = t.report()
            assert rep["enabled"] is True
            assert rep["counters"]["repro_batches_total"] == 10
            assert rep["counters"]["repro_batch_errors_total"] == 1
            assert rep["hists"]["repro_batch_seconds"]["count"] == 10
            assert "repro_stage_seconds{stage=build}" in rep["hists"]
            assert rep["series"] >= 4
        finally:
            t.close()

    def test_report_covers_schema_v4(self):
        # telemetry joined the schema in v4; later bumps are additive
        assert SCHEMA_VERSION >= 4
        assert "telemetry" in SCHEMA
        t = Telemetry(TelemetryConfig(
            slos=(SLObjective(name="lat"),)))
        try:
            t.observe_batch(0.001, {})
            t.evaluate()
            rep = t.report()
        finally:
            t.close()
        assert set(rep) == set(SCHEMA["telemetry"])

    def test_background_eval_thread(self):
        import time as _time
        t = Telemetry(TelemetryConfig(eval_every_s=0.02))
        try:
            deadline = _time.monotonic() + 5.0
            while t.evaluations == 0 and _time.monotonic() < deadline:
                _time.sleep(0.01)
            assert t.evaluations > 0
        finally:
            t.close()
        assert t._thread is None

    def test_config_validation(self):
        with pytest.raises(ValueError):
            TelemetryConfig(window_s=0)
        with pytest.raises(ValueError):
            TelemetryConfig(windows=0)
        with pytest.raises(ValueError):
            TelemetryConfig(port=70000)
        with pytest.raises(ValueError):
            TelemetryConfig(hit_floor_ratio=1.5)
        with pytest.raises(TypeError):
            TelemetryConfig(slos=("not-an-slo",))
        with pytest.raises(TypeError):
            ServingConfig(telemetry="yes")


class TestEventRing:
    def test_bounded_with_drop_accounting(self):
        ring = EventRing(capacity=4)
        for i in range(10):
            ring.emit("k", severity="info", message=str(i))
        assert len(ring) == 4
        s = ring.summary()
        assert s["emitted"] == 10 and s["dropped"] == 6
        assert [e["message"] for e in ring.snapshot()] == \
            ["6", "7", "8", "9"]

    def test_severity_filter_and_validation(self):
        ring = EventRing()
        ring.emit("a", severity="info")
        ring.emit("b", severity="crit")
        assert [e["kind"] for e in
                ring.snapshot(min_severity="warn")] == ["b"]
        with pytest.raises(ValueError):
            ring.emit("c", severity="fatal")


class TestEngineIntegration:
    def test_metrics_off_is_bitwise_identical(self, graph):
        cfg = _cfg(graph)
        targets = np.arange(12)
        outs = {}
        for name, tele in (("off", None), ("on", TelemetryConfig())):
            sc = ServingConfig(batch_size=C, num_threads=2,
                               telemetry=tele)
            with DecoupledEngine(graph, cfg, config=sc) as eng:
                outs[name] = eng.infer(targets,
                                       overlap=False).embeddings
        np.testing.assert_array_equal(outs["off"], outs["on"])

    def test_engine_wire_and_off_raises(self, graph):
        cfg = _cfg(graph)
        sc = ServingConfig(batch_size=C, num_threads=2,
                           telemetry=TelemetryConfig())
        with DecoupledEngine(graph, cfg, config=sc) as eng:
            eng.infer(np.arange(8), overlap=False)
            wire = eng.metrics_wire()
            assert series_count(wire) >= 8
            assert validate_exposition(eng.metrics_text()) == []
            rep = eng.telemetry_report()
            assert rep["counters"]["repro_batches_total"] >= 1
        with DecoupledEngine(graph, cfg,
                             config=ServingConfig(
                                 batch_size=C,
                                 num_threads=2)) as eng:
            assert eng.telemetry_report() == {"enabled": False}
            with pytest.raises(ValueError):
                eng.metrics_wire()


class TestRegressGate:
    def _points(self, *vals):
        return [{"regress": {"p50_ms": v}} for v in vals]

    def test_ok_and_regression(self):
        rows = check_trajectory(self._points(10, 11, 10, 10.5))
        assert rows[0]["status"] == "ok"
        rows = check_trajectory(self._points(10, 11, 10, 20))
        assert rows[0]["status"] == "regression"

    def test_young_trajectory_passes(self):
        rows = check_trajectory(self._points(10, 20))
        assert rows[0]["status"] == "insufficient_history"

    def test_cli_exit_codes(self, tmp_path, capsys):
        p = tmp_path / "BENCH_x.json"
        p.write_text(json.dumps(self._points(10, 10, 10, 10)))
        assert regress_main(["--results-dir", str(tmp_path)]) == 0
        p.write_text(json.dumps(self._points(10, 10, 10, 99)))
        assert regress_main(["--results-dir", str(tmp_path)]) == 1
        out = capsys.readouterr().out
        assert "REGRESSION" in out and "regress: FAIL" in out
