"""ServingConfig — the unified serving surface: validation, the
deprecated per-kwarg adapter (``from_kwargs``), the removal of
``dedup_features=``, config-first engine/server construction, and the
versioned report schema every surface now emits."""
import dataclasses

import numpy as np
import pytest

from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.core.report_schema import SCHEMA, SCHEMA_VERSION
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph
from repro.serve.gnn_server import GNNServer
from repro.store import StorePolicy


@pytest.fixture(scope="module")
def graph():
    return get_graph("flickr", scale=0.005, seed=1)   # ~450 vertices


@pytest.fixture(scope="module")
def cfg(graph):
    return GNNConfig(kind="gcn", n_layers=2, receptive_field=16,
                     f_in=graph.feature_dim)


class TestValidation:
    def test_defaults_are_local(self):
        c = ServingConfig()
        assert c.transport == "local" and not c.remote
        assert c.batch_size == 64 and c.depth == 3
        assert isinstance(c.store, StorePolicy)

    def test_frozen(self):
        with pytest.raises(dataclasses.FrozenInstanceError):
            ServingConfig().batch_size = 1

    def test_socket_needs_endpoints(self):
        with pytest.raises(ValueError, match="endpoints"):
            ServingConfig(transport="socket")

    def test_endpoints_need_socket(self):
        with pytest.raises(ValueError, match="transport='socket'"):
            ServingConfig(endpoints=("h:1",))
        with pytest.raises(ValueError, match="transport='socket'"):
            ServingConfig(transport="inproc", endpoints=("h:1",))

    def test_endpoints_list_coerced_to_tuple(self):
        c = ServingConfig(transport="socket", endpoints=["a:1", "b:2"])
        assert c.endpoints == ("a:1", "b:2") and c.remote

    @pytest.mark.parametrize("bad", [
        dict(transport="grpc"), dict(routing="random"),
        dict(batch_size=0), dict(depth=0), dict(num_threads=0),
        dict(max_inflight=0), dict(max_wait_s=-1.0),
        dict(rpc_timeout_s=0.0), dict(rpc_retries=-1),
        dict(rpc_concurrency=0), dict(store="resident"),
    ])
    def test_bad_values_rejected(self, bad):
        with pytest.raises((ValueError, TypeError)):
            ServingConfig(**bad)

    def test_describe_covers_transport(self):
        c = ServingConfig(transport="socket", endpoints=("h:1",),
                          routing="affine")
        d = c.describe()
        assert d["transport"] == "socket"
        assert d["endpoints"] == ["h:1"] and d["routing"] == "affine"
        assert "endpoints" not in ServingConfig().describe()


class TestFromKwargs:
    def test_legacy_kwargs_warn_and_map(self):
        with pytest.warns(DeprecationWarning, match="ServingConfig"):
            c = ServingConfig.from_kwargs(batch_size=8, impl="xla",
                                          num_threads=2)
        assert c.batch_size == 8 and c.num_threads == 2

    def test_unknown_kwarg_lists_valid_options(self):
        with pytest.raises(TypeError, match="unknown serving option"):
            ServingConfig.from_kwargs(batch_sise=8, _warn=False)

    def test_dedup_features_removed_names_replacement(self):
        with pytest.raises(TypeError,
                           match="dedup_features.*packed"):
            ServingConfig.from_kwargs(dedup_features=True, _warn=False)

    def test_base_overlay(self):
        base = ServingConfig(batch_size=16, depth=2)
        c = ServingConfig.from_kwargs(base=base, num_threads=3,
                                      _warn=False)
        assert (c.batch_size, c.depth, c.num_threads) == (16, 2, 3)
        assert ServingConfig.from_kwargs(base=base) is base

    def test_legacy_store_none_means_default(self):
        c = ServingConfig.from_kwargs(store=None, batch_size=4,
                                      _warn=False)
        assert isinstance(c.store, StorePolicy)


class TestEngineConstruction:
    def test_config_first_engine(self, graph, cfg):
        with DecoupledEngine(graph, cfg, config=ServingConfig(
                batch_size=4, num_threads=2)) as eng:
            assert eng.batch_size == 4 and eng.config.num_threads == 2
            out = eng.infer(np.arange(8), overlap=False).embeddings
            assert out.shape == (8, cfg.f_hidden)
            assert np.isfinite(out).all()

    def test_legacy_kwargs_still_work_with_warning(self, graph, cfg):
        with pytest.warns(DeprecationWarning):
            eng = DecoupledEngine(graph, cfg, batch_size=4,
                                  num_threads=2)
        assert eng.config.batch_size == 4
        eng.close()

    def test_legacy_kwargs_overlay_config(self, graph, cfg):
        base = ServingConfig(num_threads=2, depth=2)
        with pytest.warns(DeprecationWarning):
            eng = DecoupledEngine(graph, cfg, config=base, batch_size=4)
        assert eng.config.batch_size == 4
        assert eng.config.depth == 2          # base survives the overlay
        eng.close()

    def test_dedup_features_removed_from_engine(self, graph, cfg):
        with pytest.raises(TypeError, match="dedup_features.*packed"):
            DecoupledEngine(graph, cfg, dedup_features=True)

    def test_server_builds_engine_from_config(self, graph, cfg):
        srv = GNNServer(max_wait_s=0.005)
        srv.register("gcn", graph=graph, cfg=cfg,
                     config=ServingConfig(batch_size=4, num_threads=2))
        eng = srv.engine_for("gcn")
        assert eng.batch_size == 4
        srv.start()
        reqs = [srv.submit(i) for i in range(4)]
        srv.drain(reqs, timeout=120)
        srv.stop()
        assert all(r.embedding is not None for r in reqs)
        eng.close()

    def test_register_rejects_config_with_engine(self, graph, cfg):
        with DecoupledEngine(graph, cfg, config=ServingConfig(
                batch_size=4)) as eng:
            srv = GNNServer(max_wait_s=0.005)
            with pytest.raises(TypeError, match="config="):
                srv.register("gcn", eng, config=ServingConfig())
            with pytest.raises(TypeError, match="graph="):
                srv.register("gcn")


class TestReportSchema:
    def test_summary_is_versioned_and_nested(self, graph, cfg):
        with DecoupledEngine(graph, cfg, config=ServingConfig(
                batch_size=4, num_threads=2)) as eng:
            res = eng.infer(np.arange(8))
            s = res.stats.summary()
            assert s["schema_version"] == SCHEMA_VERSION
            for key in ("t_wall", "t_host", "t_device", "t_init"):
                assert key in s["latency"]
            assert set(s["stages"]) == {"times", "overlap", "batches",
                                        "build_hit_rate", "batch_edges"}
            for key in ("bytes_shipped", "bytes_dense", "transfer_ratio",
                        "cache_hit_rate", "dedup_ratio"):
                assert key in s["store"]
            # local deployment: no transport, no shards -> sections absent
            assert "rpc" not in s and "shards" not in s
            # every emitted key is documented in the schema contract
            for section, keys in s.items():
                if section == "schema_version":
                    continue
                assert section in SCHEMA
                for k in keys:
                    assert k in SCHEMA[section], (section, k)

    def test_server_report_is_versioned(self, graph, cfg):
        srv = GNNServer(max_wait_s=0.005)
        srv.register("gcn", graph=graph, cfg=cfg,
                     config=ServingConfig(batch_size=4, num_threads=2))
        srv.start()
        srv.drain([srv.submit(i) for i in range(4)], timeout=120)
        srv.stop()
        rep = srv.report()
        assert rep["schema_version"] == SCHEMA_VERSION
        m = rep["models"]["gcn"]
        for section in ("latency", "stages", "store", "ack"):
            assert section in m
        assert m["latency"]["n"] == 4
        assert "policy" in m["store"] and "features" in m["store"]
        assert rep["aggregate"]["latency"]["n"] == 4
        srv.engine_for("gcn").close()
