"""Sharded feature store: cross-shard gather correctness (bitwise vs the
unsharded resident store), placement policies, uneven budgets, online
PPR-mass repin(), and the per-shard observability surfaced through
SchedulerStats / GNNServer.report()."""
import numpy as np
import pytest

from repro.core.engine import DecoupledEngine
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph, zipf_traffic
from repro.store import ShardedFeatureStore, StorePolicy

TARGETS = np.arange(24)


@pytest.fixture(scope="module")
def graph():
    return get_graph("flickr", scale=0.005, seed=1)   # ~450 vertices


@pytest.fixture(scope="module")
def cfg(graph):
    return GNNConfig(kind="gcn", n_layers=2, receptive_field=32,
                     f_in=graph.feature_dim)


@pytest.fixture(scope="module")
def baseline(graph, cfg):
    """Unsharded full-resident store — the bitwise reference."""
    eng = DecoupledEngine(graph, cfg, batch_size=8,
                          store=StorePolicy(features="resident"))
    emb = eng.infer(TARGETS, overlap=False).embeddings
    yield eng, emb
    eng.close()


def _sharded(graph, cfg, params, **kw):
    kw.setdefault("num_shards", 2)
    return DecoupledEngine(graph, cfg, params=params, batch_size=8,
                           store=StorePolicy(features="sharded", **kw))


class TestPolicyValidation:
    def test_sharded_needs_num_shards(self):
        with pytest.raises(ValueError, match="num_shards"):
            StorePolicy(features="sharded")

    def test_shard_knobs_need_sharded(self):
        with pytest.raises(ValueError, match="sharded"):
            StorePolicy(num_shards=2)
        with pytest.raises(ValueError, match="sharded"):
            StorePolicy(features="resident", shard_budget_bytes=1024)

    def test_bad_placement_rejected(self):
        with pytest.raises(ValueError, match="placement"):
            StorePolicy(features="sharded", num_shards=2,
                        placement="rendezvous")

    def test_describe_includes_shard_fields(self):
        p = StorePolicy(features="sharded", num_shards=4,
                        placement="range", shard_budget_bytes=(1, 2, 3, 4))
        d = p.describe()
        assert d["num_shards"] == 4 and d["placement"] == "range"
        assert d["shard_budget_bytes"] == [1, 2, 3, 4]


class TestCrossShardGather:
    @pytest.mark.parametrize("placement", ["hash", "range"])
    @pytest.mark.parametrize("num_shards", [2, 4])
    def test_bitwise_equal_to_unsharded(self, graph, cfg, baseline,
                                        placement, num_shards):
        """Acceptance: sharded (2+ shards) == unsharded resident store,
        bitwise, for both placement policies."""
        ref, emb0 = baseline
        eng = _sharded(graph, cfg, ref.params, num_shards=num_shards,
                       placement=placement)
        emb = eng.infer(TARGETS, overlap=False).embeddings
        np.testing.assert_array_equal(emb, emb0)
        rep = eng.store_report()["features"]
        assert rep["resident_fraction"] == 1.0    # union covers the matrix
        assert rep["miss_rows_shipped"] == 0
        # 2+ shards genuinely split the table and the gather crossed them
        assert min(rep["shard_rows"]) > 0
        assert rep["cross_shard_rows"] > 0
        eng.close()

    def test_uneven_budgets_with_miss_partition(self, graph, cfg,
                                                baseline):
        """Per-shard budgets below the matrix: cold rows fall back to the
        host miss partition, results still bitwise-equal."""
        ref, emb0 = baseline
        row = graph.feature_dim * 4
        eng = _sharded(graph, cfg, ref.params, placement="range",
                       shard_budget_bytes=(96 * row, 32 * row))
        emb = eng.infer(TARGETS, overlap=False).embeddings
        np.testing.assert_array_equal(emb, emb0)
        rep = eng.store_report()["features"]
        assert rep["shard_rows"] == [96, 32]      # uneven split honored
        assert 0 < rep["resident_fraction"] < 1.0
        assert rep["miss_rows_shipped"] > 0       # host fallback exercised
        eng.close()

    def test_miss_block_ships_at_f_in(self, graph, cfg):
        """The miss block crosses the link at f_in: MXU pad columns are a
        resident-table layout concern, never shipped (and never counted
        in bytes_shipped) per batch."""
        from repro.core.ini import ini_batch
        row = graph.feature_dim * 4
        store = ShardedFeatureStore(graph, f_pad=512, num_shards=2,
                                    budget_bytes=16 * row)
        nls = ini_batch(graph, [0, 1], 32, num_threads=1)
        payload, _ = store.host_payload(nls, 32)
        assert payload["miss_feats"].shape[1] == graph.feature_dim  # 500
        # device side pads back to f_pad and reorders correctly
        feats = np.asarray(store.device_feats(payload))
        assert feats.shape == (2, 32, 512)
        np.testing.assert_array_equal(feats[0, 0, :graph.feature_dim],
                                      graph.features[nls[0][0]])
        np.testing.assert_array_equal(feats[..., graph.feature_dim:], 0.0)

    def test_single_shard_degenerates_to_resident(self, graph, cfg,
                                                  baseline):
        ref, emb0 = baseline
        eng = _sharded(graph, cfg, ref.params, num_shards=1)
        emb = eng.infer(TARGETS, overlap=False).embeddings
        np.testing.assert_array_equal(emb, emb0)
        assert eng.store_report()["features"]["cross_shard_rows"] == 0
        eng.close()


class TestRepin:
    def test_repin_promotes_hot_rows_and_stays_bitwise(self, graph, cfg,
                                                       baseline):
        """Online rebalance: after Zipf traffic, repin() promotes the
        observed-hot rows into residency; inference stays bitwise-equal
        and the hit rate does not regress."""
        ref, emb0 = baseline
        row = graph.feature_dim * 4
        # budget small enough that initial (degree-ranked) residency
        # misses part of the traffic
        eng = _sharded(graph, cfg, ref.params, placement="hash",
                       shard_budget_bytes=64 * row)
        traffic = zipf_traffic(graph, 128, a=1.1, seed=2)
        eng.infer(traffic, overlap=False)          # accumulate PPR mass
        st = eng._fsource
        lk0, res0 = st.lookups, st.resident_lookups
        report = eng.repin()
        assert report["promoted"] >= 0 and "mass_balance_after" in report
        assert st.report()["repins"] == 1
        emb = eng.infer(TARGETS, overlap=False).embeddings
        np.testing.assert_array_equal(emb, emb0)   # placement-invariant
        # replay the same traffic: observed-mass residency must serve it
        # at least as well as the degree prior did
        lk1, res1 = st.lookups, st.resident_lookups
        eng.infer(traffic, overlap=False)
        before = res0 / lk0
        after = (st.resident_lookups - res1) / (st.lookups - lk1)
        assert after >= before - 1e-9
        eng.close()

    def test_repin_requires_repinnable_store(self, graph, cfg, baseline):
        ref, _ = baseline    # resident, unsharded: repin now SUPPORTED
        rep = ref.repin()    # (PPR-mass accounting landed on the
        assert rep["resident_rows"] >= 0      # single-device store too)
        eng = DecoupledEngine(graph, cfg, params=ref.params,
                              batch_size=8)   # dense: nothing resident
        with pytest.raises(ValueError, match="repin"):
            eng.repin()
        eng.close()

    def test_inflight_placement_snapshot_survives_repin(self, graph, cfg,
                                                        baseline):
        """A payload prepared before repin() gathers against ITS placement
        generation, not the new one."""
        ref, emb0 = baseline
        eng = _sharded(graph, cfg, ref.params, num_shards=2)
        node_lists, _, _ = eng._node_lists([int(t) for t in TARGETS[:8]])
        payload, _ = eng._fsource.host_payload(node_lists, 32)
        eng.infer(zipf_traffic(graph, 64, a=1.1, seed=3), overlap=False)
        eng.repin()                                # new generation
        feats = np.asarray(eng._fsource.device_feats(payload))
        want = np.zeros_like(feats)
        for i, nl in enumerate(node_lists):
            k = min(len(nl), 32)
            want[i, :k, :graph.feature_dim] = graph.features[nl[:k]]
        np.testing.assert_array_equal(feats, want)
        eng.close()


class TestShardObservability:
    def test_scheduler_accumulates_per_shard_bytes(self, graph, cfg,
                                                   baseline):
        ref, _ = baseline
        eng = _sharded(graph, cfg, ref.params, num_shards=2)
        eng.infer(TARGETS, overlap=False)
        s = eng.scheduler.stats
        assert len(s.shard_bytes) == 2 and all(b > 0 for b in s.shard_bytes)
        assert s.shard_balance >= 1.0
        assert s.summary()["shards"]["balance"] >= 1.0
        # index-only: per-shard bytes are a small fraction of dense
        assert sum(s.shard_bytes) < s.bytes_dense
        eng.close()

    def test_server_report_surfaces_shard_stats(self, graph, cfg):
        from repro.serve.gnn_server import GNNServer
        eng = DecoupledEngine(graph, cfg, batch_size=4,
                              store=StorePolicy(features="sharded",
                                                num_shards=2,
                                                nbr_cache="lru"))
        srv = GNNServer(eng, max_wait_s=0.005)
        srv.start()
        reqs = [srv.submit(int(t)) for t in [0, 1, 2, 3, 0, 1, 2, 3]]
        srv.drain(reqs, timeout=120)
        srv.stop()
        m = srv.report()["models"]["default"]
        assert len(m["shards"]["bytes"]) == 2
        assert m["shards"]["balance"] >= 1.0
        st = m["store"]["features"]
        assert st["strategy"] == "sharded" and st["num_shards"] == 2
        for key in ("shard_rows", "shard_lookups", "mass_balance",
                    "cross_shard_rows", "placement", "simulated"):
            assert key in st
        eng.close()

    def test_graph_update_refreshes_shard_rows(self, graph, cfg):
        """Feature half of the update hook, sharded edition: mutated rows
        re-upload into their shard tables."""
        import copy
        g = copy.deepcopy(graph)
        eng = DecoupledEngine(g, cfg, batch_size=8,
                              store=StorePolicy(features="sharded",
                                                num_shards=2,
                                                nbr_cache="lru"))
        t = np.arange(8)
        before = eng.infer(t, overlap=False).embeddings
        g.features[:8] += 1.0
        eng.invalidate(np.arange(8))
        after = eng.infer(t, overlap=False).embeddings
        assert np.abs(after - before).max() > 0
        fresh = DecoupledEngine(g, cfg, params=eng.params, batch_size=8)
        np.testing.assert_allclose(
            after, fresh.infer(t, overlap=False).embeddings,
            rtol=1e-6, atol=1e-6)
        fresh.close()
        eng.close()
