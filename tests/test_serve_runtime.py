"""Multi-model streaming serving runtime (the paper's §4.4/§4.5 deployment
shape): persistent streaming scheduler, engine without per-call pipeline
reconstruction, and the multi-tenant GNNServer under one shared DSEPlan."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dse import PlanViolation, TPUSpec, explore, plan_covers
from repro.core.engine import DecoupledEngine
from repro.core.scheduler import PipelineScheduler
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph
from repro.serve.gnn_server import GNNServer


@pytest.fixture(scope="module")
def graph():
    return get_graph("flickr", scale=0.02, seed=1)   # ~1.8k vertices


def make_engine(graph, kind, batch_size=8, n_layers=2, n=32):
    cfg = GNNConfig(kind=kind, n_layers=n_layers, receptive_field=n,
                    f_in=graph.feature_dim)
    return DecoupledEngine(graph, cfg, batch_size=batch_size)


class TestStreamingScheduler:
    def test_submit_poll_lifecycle(self):
        def host(x):
            return x * 2

        def dev(x):
            return jnp.asarray(x + 1)

        s = PipelineScheduler(host, dev, depth=2)
        tickets = [s.submit(i) for i in range(5)]
        outs = [int(t.result(timeout=10)) for t in tickets]
        assert outs == [2 * i + 1 for i in range(5)]
        assert all(t.done() for t in tickets)
        s.close()

    def test_cumulative_stats_across_calls(self):
        s = PipelineScheduler(lambda x: x, jnp.asarray, depth=2)
        _, call1 = s.run(list(range(3)))
        _, call2 = s.run(list(range(4)))
        assert call1.n_batches == 3 and call2.n_batches == 4
        # cumulative stats keep accumulating over the scheduler lifetime
        assert s.stats.n_batches == 7
        assert len(s.stats.host_times) == 7
        t = s.submit(9)
        t.result(timeout=10)
        assert s.stats.n_batches == 8
        assert s.stats.t_initialization == s.stats.host_times[0]
        s.close()

    def test_bounded_inflight_backpressure(self):
        release = threading.Event()

        def slow_dev(x):
            release.wait(5)
            return jnp.asarray(x)

        s = PipelineScheduler(lambda x: x, slow_dev, depth=1,
                              max_inflight=2)
        t0 = s.submit(0)
        s.submit(1)
        # both slots taken; a third submit must block until one completes
        done = threading.Event()

        def third():
            s.submit(2)
            done.set()

        threading.Thread(target=third, daemon=True).start()
        assert not done.wait(0.2)
        release.set()
        assert done.wait(5)
        t0.result(timeout=10)
        s.flush(timeout=10)
        assert s.stats.n_batches == 3
        s.close()

    def test_host_error_propagates(self):
        def bad_host(x):
            raise RuntimeError("boom")

        s = PipelineScheduler(bad_host, jnp.asarray, depth=2)
        t = s.submit(1)
        with pytest.raises(RuntimeError, match="boom"):
            t.result(timeout=10)
        s.flush(timeout=10)   # pipeline survives the failed batch
        ok = s.submit(2)      # ...but host_fn still raises; error isolated
        with pytest.raises(RuntimeError):
            ok.result(timeout=10)
        s.close()

    def test_on_done_callback_fires(self):
        got = []
        s = PipelineScheduler(lambda x: x, jnp.asarray, depth=2)
        t = s.submit(7, on_done=lambda tk: got.append(int(tk.result())))
        t.result(timeout=10)
        s.flush(timeout=10)
        assert got == [7]
        s.close()


class TestPersistentEngine:
    def test_no_scheduler_reconstruction_per_batch(self, graph):
        eng = make_engine(graph, "gcn")
        sched = eng.scheduler
        r1 = eng.infer(np.arange(20))             # 3 micro-batches
        r2 = eng.infer(np.arange(20, 36))         # 2 micro-batches
        # the SAME scheduler served every micro-batch of both calls
        assert eng.scheduler is sched
        assert sched.stats.n_batches == 5
        assert r1.stats.n_batches == 3 and r2.stats.n_batches == 2
        eng.close()

    def test_streaming_matches_batch(self, graph):
        eng = make_engine(graph, "sage")
        targets = np.arange(8)
        ref = eng.infer(targets, overlap=False).embeddings
        tk = eng.submit_chunk(targets)
        out = np.asarray(tk.result(timeout=60))
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
        eng.close()

    def test_tail_padding(self, graph):
        eng = make_engine(graph, "gcn")
        padded = eng.pad_targets(np.array([3, 4, 5]))
        assert padded.shape == (8,)
        assert (padded[3:] == 5).all()
        with pytest.raises(ValueError):
            eng.pad_targets(np.arange(9))
        res = eng.infer(np.arange(11))            # tail chunk of 3
        assert res.embeddings.shape == (11, eng.cfg.f_hidden)
        assert np.isfinite(res.embeddings).all()
        eng.close()


class TestSharedPlan:
    def test_plan_covers(self):
        cfgs = [GNNConfig(kind=k, n_layers=2, receptive_field=64, f_in=128)
                for k in ("gcn", "sage", "gat")]
        plan = explore(cfgs)
        for c in cfgs:
            assert plan_covers(plan, c) == []
        monster = GNNConfig(kind="gcn", n_layers=2, receptive_field=4096,
                            f_in=4096)
        assert plan_covers(plan, monster, TPUSpec()) != []

    def test_register_rejects_model_outside_fixed_plan(self, graph):
        eng = make_engine(graph, "gcn")
        tight = TPUSpec(vmem_bytes=2 ** 10)       # nothing fits 1 KiB
        plan = explore([eng.cfg])
        srv = GNNServer(max_wait_s=0.01, plan=plan, spec=tight)
        with pytest.raises(PlanViolation):
            srv.register("gcn", eng)
        eng.close()


class TestMultiModelServer:
    def test_two_kinds_concurrently_match_standalone(self, graph):
        engines = {k: make_engine(graph, k) for k in ("gcn", "sage")}
        srv = GNNServer(max_wait_s=0.01)
        for k, e in engines.items():
            srv.register(k, e)
        assert srv.plan is not None and srv.plan.ops_ok
        srv.start()
        rng = np.random.default_rng(0)
        reqs = []
        for t in rng.integers(0, graph.num_vertices, 32):
            reqs.append(srv.submit(int(t), model="gcn"))
            reqs.append(srv.submit(int(t) % 97, model="sage"))
        srv.drain(reqs, timeout=300)
        srv.stop()
        assert all(r.embedding is not None for r in reqs)
        # routed + micro-batched + padded results == standalone engine.infer
        for kind in ("gcn", "sage"):
            mine = [r for r in reqs if r.model == kind]
            tgts = np.array([r.target for r in mine])
            ref = engines[kind].infer(tgts, overlap=False).embeddings
            got = np.stack([r.embedding for r in mine])
            np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
        rep = srv.report()
        for kind in ("gcn", "sage"):
            m = rep["models"][kind]
            assert m["latency"]["n"] == 32
            assert m["latency"]["p50"] <= m["latency"]["p90"] \
                <= m["latency"]["p99"]
            assert 0.0 <= m["stages"]["overlap"] <= 1.0
        assert rep["plan"]["block_f"] % 128 == 0
        for e in engines.values():
            e.close()

    def test_single_model_backcompat(self, graph):
        eng = make_engine(graph, "gcn")
        srv = GNNServer(eng, max_wait_s=0.01)     # legacy positional form
        srv.start()
        reqs = [srv.submit(i) for i in range(10)]
        srv.drain(reqs, timeout=120)
        srv.stop()
        p = srv.stats.percentiles()
        assert p["n"] == 10 and p["p99"] > 0
        eng.close()

    def test_unknown_model_rejected(self, graph):
        eng = make_engine(graph, "gcn")
        srv = GNNServer(max_wait_s=0.01)
        srv.register("gcn", eng)
        srv.register("gcn2", make_engine(graph, "gcn"))
        with pytest.raises(ValueError):
            srv.submit(0)                          # ambiguous: two models
        with pytest.raises(KeyError):
            srv.submit(0, model="nope")
        with pytest.raises(ValueError):
            srv.register("gcn", eng)               # duplicate name
        eng.close()

    def test_drain_raises_on_failed_batch(self, graph):
        """A host-side failure surfaces through drain() with its cause,
        instead of burning the whole drain timeout."""
        eng = make_engine(graph, "gcn", batch_size=4)
        srv = GNNServer(eng, max_wait_s=0.01)
        srv.start()
        bad = srv.submit(graph.num_vertices + 10**6)   # out-of-range vertex
        with pytest.raises(RuntimeError, match="failed"):
            srv.drain([bad], timeout=120)
        srv.stop()
        eng.close()

    def test_server_restart_serves_again(self, graph):
        """stop() then start() must serve (lane stop flags are cleared)."""
        eng = make_engine(graph, "gcn", batch_size=4)
        srv = GNNServer(eng, max_wait_s=0.01)
        srv.start()
        r1 = [srv.submit(i) for i in range(4)]
        srv.drain(r1, timeout=120)
        srv.stop()
        srv.start()
        r2 = [srv.submit(i) for i in range(4)]
        srv.drain(r2, timeout=120)
        srv.stop()
        np.testing.assert_allclose(np.stack([r.embedding for r in r1]),
                                   np.stack([r.embedding for r in r2]),
                                   rtol=1e-6)
        eng.close()

    def test_partial_tail_batch_padded_per_lane(self, graph):
        """Requests that don't fill C still come back correct (the lane
        pads the tail micro-batch with repeated targets)."""
        eng = make_engine(graph, "gcn", batch_size=8)
        srv = GNNServer(max_wait_s=0.01)
        srv.register("gcn", eng)
        srv.start()
        reqs = [srv.submit(i, model="gcn") for i in (5, 6, 7)]  # 3 < C=8
        srv.drain(reqs, timeout=120)
        srv.stop()
        ref = eng.infer(np.array([5, 6, 7]), overlap=False).embeddings
        got = np.stack([r.embedding for r in reqs])
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
        time.sleep(0)   # lanes joined in stop(); nothing left in flight
        assert srv.model_stats("gcn").n_batches >= 1
        eng.close()
