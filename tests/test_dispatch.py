"""Per-batch adaptive dispatch: policy, variant cache, warmup schedule,
calibration persistence, Pallas block autotune, and the bitwise-identity
contract (a dispatch-enabled run serves the same bits as the matching
forced-mode engine)."""
import numpy as np
import pytest

from repro.core.config import ServingConfig
from repro.core.dispatch import (DispatchConfig, DispatchPolicy,
                                 VariantCache, variant_key)
from repro.core.engine import DecoupledEngine
from repro.core.program import compile_steps, mux_sites, respecialize
from repro.gnn.model import GNNConfig
from repro.graphs.csr import from_edge_list
from repro.graphs.synthetic import get_graph
from repro.obs.calib import (CalibrationArtifactError, CalibrationTable,
                             WarmupSchedule, best_block, load_calibration,
                             op_label, op_mode, save_calibration)

KINDS = ("gcn", "sage", "gin", "gat")
N = 16


@pytest.fixture(scope="module")
def graph():
    return get_graph("flickr", scale=0.005, seed=1)   # ~450 vertices


def sparse_graph(v=512, edges=48, f=64, seed=0):
    """Mean degree << 1: the regime where sg aggregation wins."""
    rng = np.random.default_rng(seed)
    src = rng.choice(v, edges, replace=False)
    dst = (src + 1 + rng.integers(0, v - 1, edges)) % v
    feats = rng.standard_normal((v, f)).astype(np.float32)
    return from_edge_list(src, dst, v, feats, name="ultra-sparse")


def make_cfg(g, kind="gcn"):
    return GNNConfig(kind=kind, n_layers=2, receptive_field=N,
                     f_in=g.feature_dim, f_hidden=128)


def serve(g, cfg, params, config, targets):
    with DecoupledEngine(g, cfg, params=params, config=config) as eng:
        out = eng.infer(targets).embeddings
        rep = eng.dispatch_report()
    return out, rep


# ---------------------------------------------------------------------------


class TestVariantCache:
    def test_bounded_lru_with_counters(self):
        vc = VariantCache(capacity=2)
        fns = {}
        for k in ("a", "b", "c"):
            fns[k] = vc.get(k, lambda k=k: (lambda: k))
        assert len(vc) == 2                       # bounded
        assert vc.evictions == 1 and vc.misses == 3 and vc.hits == 0
        assert "a" not in vc.keys()               # LRU order: a evicted
        # in-flight safety: the evicted entry's holder still runs it
        assert fns["a"]() == "a"
        assert vc.get("b", lambda: None)() == "b"  # hit, no rebuild
        assert vc.hits == 1

    def test_lru_recency(self):
        vc = VariantCache(capacity=2)
        vc.get("a", lambda: "A")
        vc.get("b", lambda: "B")
        vc.get("a", lambda: "never")              # touch a -> b is LRU
        vc.get("c", lambda: "C")
        assert set(vc.keys()) == {"a", "c"}

    def test_validation(self):
        with pytest.raises(ValueError):
            VariantCache(capacity=0)
        with pytest.raises(ValueError):
            DispatchConfig(variant_capacity=0)
        with pytest.raises(ValueError):
            DispatchConfig(warmup_passes=-1)
        with pytest.raises(TypeError, match="DispatchConfig"):
            ServingConfig(dispatch=object())

    def test_variant_key_canonical(self):
        a = variant_key({"x": "sg", "y": "dense"}, {"block_f": 128})
        b = variant_key({"y": "dense", "x": "sg"}, {"block_f": 128})
        assert a == b
        assert variant_key({}, {"block_f": None}) == variant_key({}, {})


class TestWarmupSchedule:
    def test_deterministic_and_alternating(self):
        h = []
        for _ in range(2):
            ws = WarmupSchedule(passes=2, seed=7)
            seq = [ws.next_mode(9) for _ in range(5)]
            h.append(seq)
        assert h[0] == h[1]                       # seeded determinism
        assert h[0][4] is None                    # exhausted at 2*passes
        modes = h[0][:4]
        assert modes[0] != modes[1] and modes[2] != modes[3]
        assert set(modes) == {"dense", "sg"}      # both sides explored

    def test_per_bucket_state(self):
        ws = WarmupSchedule(passes=1, seed=0)
        ws.next_mode(5)
        assert ws.active(5) and not ws.active(5) is None
        ws.next_mode(5)
        assert not ws.active(5)
        assert ws.active(6)                       # other buckets untouched
        assert ws.state()["done"] == {5: 2}


class TestRespecialize:
    def test_validation(self, graph):
        cfg = make_cfg(graph)
        with DecoupledEngine(graph, cfg, config=ServingConfig(
                batch_size=2)) as eng:
            prog = eng.program
        sites = mux_sites(prog)
        assert sites                              # gcn has mux'd Aggregates
        with pytest.raises(KeyError):
            respecialize(prog, {"layer0[99]": "sg"})
        with pytest.raises(ValueError, match="no dense/sg mux"):
            respecialize(prog, {"tail[0]": "sg"})
        with pytest.raises(ValueError):
            respecialize(prog, {sites[0]: "systolic"})
        # unlisted sites keep their mode; listed flip
        flipped = respecialize(prog, {sites[0]: "sg"})
        assert dict(flipped.ops)[sites[0]].mode == "sg"
        assert flipped.specialized


# ---------------------------------------------------------------------------
# the bitwise-identity contract


class TestAdaptiveBitwise:
    @pytest.mark.parametrize("impl", ("xla", "pallas"))
    @pytest.mark.parametrize("kind", KINDS)
    def test_auto_equals_matching_forced(self, graph, kind, impl):
        """Per-batch auto on the hub-dense regime serves bit-identical
        embeddings to the forced engine of the mode it picks, for every
        kind x impl. (Warmup instrumented passes discard outputs, so
        this holds during exploration too — warmup_passes=1 keeps them
        in the loop; autotune stays off because tuned block_e regroups
        fp32 accumulation.)"""
        import jax
        from repro.gnn.model import init_gnn
        cfg = make_cfg(graph, kind)
        params = init_gnn(cfg, jax.random.PRNGKey(3))
        targets = np.arange(4)
        dc = DispatchConfig(warmup_passes=1, autotune_blocks=False)
        auto, rep = serve(graph, cfg, params, ServingConfig(
            batch_size=2, mode="auto", dispatch=dc), targets)
        # the dense regime picks dense on every mux site, so the
        # matching forced engine is the all-dense one
        assert rep["decisions"] == 2
        forced, _ = serve(graph, cfg, params, ServingConfig(
            batch_size=2, mode="dense"), targets)
        np.testing.assert_array_equal(auto, forced)

    @pytest.mark.parametrize("impl", ("xla", "pallas"))
    @pytest.mark.parametrize("kind", ("gcn", "sage"))
    def test_auto_equals_forced_sg_on_sparse(self, kind, impl):
        import jax
        from repro.gnn.model import init_gnn
        g = sparse_graph()
        cfg = make_cfg(g, kind)
        params = init_gnn(cfg, jax.random.PRNGKey(3))
        targets = np.arange(4)
        dc = DispatchConfig(warmup_passes=1, autotune_blocks=False)
        auto, rep = serve(g, cfg, params, ServingConfig(
            batch_size=2, mode="auto", impl=impl, dispatch=dc), targets)
        forced, _ = serve(g, cfg, params, ServingConfig(
            batch_size=2, mode="sg", impl=impl), targets)
        np.testing.assert_array_equal(auto, forced)


class TestMeasuredDispatch:
    def test_injected_table_forces_sg_bitwise(self, graph):
        """A table whose cells make sg cheaper flips serving to all-sg
        from the FIRST batch (no warmup consumed), bit-identical to the
        forced sg engine — measured costs really drive the mux."""
        import jax
        from repro.gnn.model import init_gnn
        cfg = make_cfg(graph)
        params = init_gnn(cfg, jax.random.PRNGKey(3))
        targets = np.arange(4)
        dc = DispatchConfig(warmup_passes=0, autotune_blocks=False)
        with DecoupledEngine(graph, cfg, params=params,
                             config=ServingConfig(batch_size=2,
                                                  mode="auto",
                                                  dispatch=dc)) as eng:
            pol = eng.dispatch
            bucket = int(2 * N).bit_length()      # C*N of this engine
            for sec, _ in eng.program.layer_sections():
                sites = [s for s in pol.sites if s.startswith(sec)]
                for mode, cost in (("dense", 1.0), ("sg", 1e-6)):
                    seq = getattr(respecialize(
                        eng.program, {s: mode for s in sites}), sec)
                    for ops, _ in compile_steps(seq, eng.impl):
                        pol.table.record(op_label(ops),
                                         op_mode(ops, eng.impl),
                                         bucket, cost)
            auto = eng.infer(targets).embeddings
            rep = eng.dispatch_report()
        assert rep["sources"]["measured"] == rep["decisions"] > 0
        assert rep["sources"]["warmup"] == 0
        forced, _ = serve(graph, cfg, params, ServingConfig(
            batch_size=2, mode="sg"), targets)
        np.testing.assert_array_equal(auto, forced)

    def test_warmup_then_exploit_deterministic(self, graph):
        import jax
        from repro.gnn.model import init_gnn
        cfg = make_cfg(graph)
        params = init_gnn(cfg, jax.random.PRNGKey(3))
        dc = DispatchConfig(warmup_passes=1, seed=11,
                            autotune_blocks=False)
        histories = []
        for _ in range(2):
            with DecoupledEngine(graph, cfg, params=params,
                                 config=ServingConfig(
                                     batch_size=2, mode="auto",
                                     dispatch=dc)) as eng:
                eng.infer(np.arange(8))           # 4 batches
                rep = eng.dispatch_report()
                histories.append(list(eng.dispatch.warmup.history))
        assert histories[0] == histories[1]       # seeded determinism
        # 2 warmup slots (1 pass per side), then measured exploitation
        assert rep["sources"]["warmup"] == 2
        assert rep["sources"]["measured"] == 2
        assert rep["sources"]["flop"] == 0
        assert rep["warmup"]["done"] == {int(2 * N).bit_length(): 2}

    def test_forced_mode_keeps_policy_inert(self, graph):
        cfg = make_cfg(graph)
        with DecoupledEngine(graph, cfg, config=ServingConfig(
                batch_size=2, mode="sg",
                dispatch=DispatchConfig())) as eng:
            eng.infer(np.arange(4))
            rep = eng.dispatch_report()
            assert eng.dispatch is None           # policy never built
        assert rep["policy"] == "forced"
        assert rep["sources"] == {"forced": 2}


# ---------------------------------------------------------------------------
# Pallas block autotune


class TestBlockAutotune:
    def test_best_block_requires_full_grid(self):
        t = CalibrationTable()
        cands = (64, 128, 256)
        assert best_block(t, "fused_gnn", "bf=", cands, 7) is None
        # the tuner records every legal candidate in one pass per
        # bucket, so per-bucket legality == "has a cell at this bucket";
        # cells at OTHER buckets do not leak in
        t.record("fused_gnn", "pallas/bf=64", 7, 2e-3)
        t.record("fused_gnn", "pallas/bf=128", 7, 1e-3)
        t.record("fused_gnn", "pallas/bf=256", 8, 9e-4)
        assert best_block(t, "fused_gnn", "bf=", cands, 7) == 128
        assert best_block(t, "fused_gnn", "bf=", cands, 8) == 256
        t.record("fused_gnn", "pallas/bf=256", 7, 5e-4)
        assert best_block(t, "fused_gnn", "bf=", cands, 7) == 256

    def test_autotune_records_cells_and_policy_consumes(self):
        """run_block_autotune populates (kernel, pallas/b*=) cells for
        every legal candidate; the policy's block overrides appear once
        the grid is complete."""
        import jax
        from repro.core.program import lower_and_specialize
        from repro.gnn.model import init_gnn
        from repro.core.subgraph import build_batch
        from repro.kernels.fused_gnn import BLOCK_F_CANDIDATES
        from repro.kernels.scatter_gather import BLOCK_E_CANDIDATES
        from repro.obs.calib import run_block_autotune, size_bucket
        g = get_graph("flickr", scale=0.005, seed=1)
        cfg = make_cfg(g)
        params = init_gnn(cfg, jax.random.PRNGKey(0))
        prog, _ = lower_and_specialize(cfg, force="dense")
        sb = build_batch(g, [1, 2], N, e_pad=64, num_threads=1)
        with DecoupledEngine(g, cfg, params=params, config=ServingConfig(
                batch_size=2, mode="sg")) as eng:
            batch = eng.device_batch(sb)
        batch.setdefault("adj", sb.adj)
        table = CalibrationTable()
        run_block_autotune(prog, params, batch, table)
        bucket = size_bucket(batch)
        fout = params["layer0"]["w"].shape[1]
        legal_bf = [b for b in BLOCK_F_CANDIDATES
                    if b <= fout and fout % b == 0]
        for b in legal_bf:
            assert table.lookup("fused_gnn", f"pallas/bf={b}",
                                bucket) is not None
        for b in BLOCK_E_CANDIDATES:
            assert table.lookup("scatter_gather", f"pallas/be={b}",
                                bucket) is not None
        pol = DispatchPolicy(prog, "pallas", table, n=N,
                             f_in=cfg.f_in, f_hidden=cfg.f_hidden)
        blocks = pol._blocks(bucket)
        assert blocks.get("block_f") in legal_bf
        assert blocks.get("block_e") in BLOCK_E_CANDIDATES


# ---------------------------------------------------------------------------
# persistence


class TestPersistence:
    def _table(self):
        t = CalibrationTable()
        for i, v in enumerate((1e-4, 2e-4, 3e-4, 5e-3)):
            t.record("Aggregate", "xla/dense", 7, v)
            t.record("Aggregate", "xla/sg", 7, v * 0.1)
        t.passes = 4
        return t

    def test_roundtrip_is_lossless(self, graph, tmp_path):
        cfg = make_cfg(graph)
        t = self._table()
        path = str(tmp_path / "calib")
        save_calibration(path, t, graph=graph, cfg=cfg, impl="xla")
        t2 = load_calibration(path, graph=graph, cfg=cfg, impl="xla")
        assert t2.passes == t.passes and len(t2) == len(t)
        for mode in ("xla/dense", "xla/sg"):
            assert t2.lookup("Aggregate", mode, 7) == \
                t.lookup("Aggregate", mode, 7)    # bit-identical p50s

    def test_stale_artifact_refuses(self, graph, tmp_path):
        cfg = make_cfg(graph)
        path = str(tmp_path / "calib")
        save_calibration(path, self._table(), graph=graph, cfg=cfg,
                         impl="xla")
        other_cfg = GNNConfig(kind="gcn", n_layers=2, receptive_field=N,
                              f_in=graph.feature_dim, f_hidden=256)
        with pytest.raises(CalibrationArtifactError, match="rebuild"):
            load_calibration(path, graph=graph, cfg=other_cfg,
                             impl="xla")
        with pytest.raises(CalibrationArtifactError, match="impl|model"):
            load_calibration(path, graph=graph, cfg=cfg, impl="pallas")
        g2 = sparse_graph()
        with pytest.raises(CalibrationArtifactError,
                           match="graph_fingerprint"):
            load_calibration(path, graph=g2, cfg=make_cfg(g2),
                             impl="xla")

    def test_engine_saves_on_close_and_restarts_warm(self, graph,
                                                     tmp_path):
        import jax
        from repro.ckpt.checkpoint import committed_steps
        from repro.gnn.model import init_gnn
        cfg = make_cfg(graph)
        params = init_gnn(cfg, jax.random.PRNGKey(3))
        path = str(tmp_path / "calib")
        dc = DispatchConfig(warmup_passes=1, autotune_blocks=False,
                            artifact=path)
        sconf = ServingConfig(batch_size=2, mode="auto", dispatch=dc)
        with DecoupledEngine(graph, cfg, params=params,
                             config=sconf) as eng:
            eng.infer(np.arange(8))               # warmup fills the table
            cells = len(eng._calib)
        assert committed_steps(path)              # close() persisted it
        assert cells > 0
        with DecoupledEngine(graph, cfg, params=params,
                             config=sconf) as eng:
            assert len(eng._calib) == cells       # loaded, not rebuilt
            eng.infer(np.arange(4))
            rep = eng.dispatch_report()
        # persisted cells -> measured from the FIRST batch, no warmup
        assert rep["sources"] == {"measured": 2, "flop": 0,
                                  "warmup": 0, "forced": 0}


# ---------------------------------------------------------------------------
# observability surfaces


class TestObservability:
    def test_report_keys_covered_by_schema(self, graph):
        from repro.core.report_schema import SCHEMA
        cfg = make_cfg(graph)
        with DecoupledEngine(graph, cfg, config=ServingConfig(
                batch_size=2, mode="auto",
                dispatch=DispatchConfig(warmup_passes=1,
                                        autotune_blocks=False))) as eng:
            eng.infer(np.arange(4))
            rep = eng.dispatch_report()
        assert rep["enabled"] is True
        assert set(rep) <= set(SCHEMA["dispatch"])
        assert rep["variants"]["size"] <= rep["variants"]["capacity"]

    def test_dispatch_metrics_exposed(self, graph):
        from repro.obs.metrics import TelemetryConfig
        cfg = make_cfg(graph)
        with DecoupledEngine(graph, cfg, config=ServingConfig(
                batch_size=2, mode="auto",
                telemetry=TelemetryConfig(),
                dispatch=DispatchConfig(warmup_passes=1,
                                        autotune_blocks=False))) as eng:
            eng.infer(np.arange(8))
            text = eng.metrics_text(cluster=False)
        assert "repro_dispatch_total" in text
        assert 'source="warmup"' in text
        assert "repro_variant_cache_hits_total" in text
        assert "repro_dispatch_decisions_total" in text

    def test_scheduler_surfaces_batch_edges(self, graph):
        cfg = make_cfg(graph)
        with DecoupledEngine(graph, cfg, config=ServingConfig(
                batch_size=2)) as eng:
            res = eng.infer(np.arange(4))
        s = res.stats.summary()
        assert s["stages"]["batch_edges"] > 0
