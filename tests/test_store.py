"""Store subsystem: device feature store, neighborhood cache, StorePolicy
wiring through engine/scheduler/server — plus the packed-features and
pad_targets coverage the subsystem leans on."""
import numpy as np
import pytest

from repro.core.config import ServingConfig
from repro.core.engine import DecoupledEngine
from repro.core.ini import ini_batch
from repro.core.subgraph import batch_from_node_lists, packed_features
from repro.gnn.model import GNNConfig
from repro.graphs.synthetic import get_graph
from repro.store import (DeviceFeatureStore, NeighborhoodCache, StorePolicy,
                         nbr_key)


@pytest.fixture(scope="module")
def graph():
    return get_graph("flickr", scale=0.005, seed=1)   # ~450 vertices


@pytest.fixture(scope="module")
def cfg(graph):
    return GNNConfig(kind="gcn", n_layers=2, receptive_field=32,
                     f_in=graph.feature_dim)


@pytest.fixture(scope="module")
def baseline(graph, cfg):
    eng = DecoupledEngine(graph, cfg, batch_size=8)
    emb = eng.infer(np.arange(24), overlap=False).embeddings
    yield eng, emb
    eng.close()


class TestStorePolicy:
    def test_rejects_unknown_modes(self):
        with pytest.raises(ValueError):
            StorePolicy(features="hbm")
        with pytest.raises(ValueError):
            StorePolicy(nbr_cache="fifo")
        with pytest.raises(ValueError):
            StorePolicy(nbr_capacity=0)
        with pytest.raises(ValueError):       # pins need pinned mode
            StorePolicy(nbr_cache="lru", pinned_targets=(1, 2))

    def test_dedup_features_removed(self, graph, cfg):
        # the long-deprecated pre-store spelling is gone; the error names
        # the replacement so old callers know where to go
        with pytest.raises(TypeError, match="dedup_features.*packed"):
            DecoupledEngine(graph, cfg, batch_size=8,
                            dedup_features=True)
        # the replacement spelling still exposes the back-compat flag
        eng = DecoupledEngine(
            graph, cfg, config=ServingConfig(
                batch_size=8, store=StorePolicy(features="packed")))
        assert eng.store_policy.features == "packed"
        assert eng.dedup_features
        eng.close()


class TestPackedFeatures:
    def test_reconstruction_exact_vs_dense(self, graph):
        """uniq[idx] must reproduce the dense [C, N, f] block bitwise,
        including zero rows for padded subgraph slots."""
        n = 32
        targets = list(range(12))
        nls = ini_batch(graph, targets, n, num_threads=1)
        sb = batch_from_node_lists(graph, targets, nls, n,
                                   e_pad=4 * n * 8)
        uniq, idx, ratio = packed_features(nls, graph, n)
        np.testing.assert_array_equal(uniq[idx], sb.feats)
        assert 0 < ratio < 1.0                    # hubs recur -> savings

    def test_pad_row_is_zero(self, graph):
        nls = ini_batch(graph, [0, 1], 32, num_threads=1)
        uniq, idx, _ = packed_features(nls, graph, 32)
        assert np.all(uniq[0] == 0)
        short = min(len(nl) for nl in nls)
        if short < 32:                            # padded slots hit row 0
            assert np.all(idx[:, short:] >= 0)


class TestPadTargets:
    def test_pads_tail_by_repeating_last(self, baseline):
        eng, _ = baseline
        out = eng.pad_targets(np.array([3, 5]))
        assert len(out) == eng.batch_size
        assert list(out[:2]) == [3, 5] and np.all(out[2:] == 5)

    def test_full_chunk_passthrough(self, baseline):
        eng, _ = baseline
        t = np.arange(eng.batch_size)
        np.testing.assert_array_equal(eng.pad_targets(t), t)

    def test_oversize_chunk_raises(self, baseline):
        eng, _ = baseline
        with pytest.raises(ValueError):
            eng.pad_targets(np.arange(eng.batch_size + 1))

    def test_empty_chunk_raises(self, baseline):
        eng, _ = baseline
        with pytest.raises(ValueError):
            eng.pad_targets(np.array([], dtype=np.int64))


class TestNeighborhoodCache:
    def test_lru_eviction_order(self):
        c = NeighborhoodCache(capacity=2)
        k = [nbr_key(t, 8, 0.15, 1e-4) for t in range(3)]
        c.put(k[0], np.array([0]))
        c.put(k[1], np.array([1]))
        assert c.get(k[0]) is not None            # 0 now most-recent
        c.put(k[2], np.array([2]))                # evicts 1, not 0
        assert c.evictions == 1
        assert k[1] not in c and k[0] in c and k[2] in c

    def test_pinned_never_evicts(self):
        c = NeighborhoodCache(capacity=1, pinned_targets=[7])
        kp = nbr_key(7, 8, 0.15, 1e-4)
        c.put(kp, np.array([7]))
        for t in range(20):
            c.put(nbr_key(t, 8, 0.15, 1e-4), np.array([t]))
        assert kp in c and c.get(kp) is not None

    def test_invalidate_drops_touching_entries(self):
        c = NeighborhoodCache(capacity=8, pinned_targets=[1])
        c.put(nbr_key(1, 8, 0.15, 1e-4), np.array([1, 5, 9]))   # pinned
        c.put(nbr_key(2, 8, 0.15, 1e-4), np.array([2, 5]))
        c.put(nbr_key(3, 8, 0.15, 1e-4), np.array([3, 4]))
        assert c.invalidate([5]) == 2             # pinned included
        assert len(c) == 1 and c.invalidations == 2

    def test_put_dropped_across_invalidate_generation(self):
        """A neighborhood computed before an invalidate() must not land:
        it may reflect the pre-update graph."""
        c = NeighborhoodCache(capacity=8)
        k = nbr_key(1, 8, 0.15, 1e-4)
        gen = c.generation                    # miss -> start computing
        c.invalidate([1])                     # graph update mid-flight
        c.put(k, np.array([1, 2]), generation=gen)
        assert k not in c                     # stale insert dropped
        c.put(k, np.array([1, 2]), generation=c.generation)
        assert k in c                         # fresh insert lands

    def test_distinct_ppr_params_distinct_keys(self):
        c = NeighborhoodCache(capacity=8)
        c.put(nbr_key(1, 8, 0.15, 1e-4), np.array([1]))
        assert c.get(nbr_key(1, 8, 0.15, 1e-5)) is None
        assert c.get(nbr_key(1, 16, 0.15, 1e-4)) is None


class TestEngineWithStore:
    def _engine(self, graph, cfg, params, **store_kw):
        return DecoupledEngine(graph, cfg, params=params, batch_size=8,
                               store=StorePolicy(**store_kw))

    def test_cached_equals_cold_bitwise(self, graph, cfg, baseline):
        ref, emb0 = baseline
        eng = self._engine(graph, cfg, ref.params, nbr_cache="lru",
                           nbr_capacity=64)
        t = np.arange(24)
        cold = eng.infer(t, overlap=False).embeddings
        cached = eng.infer(t, overlap=False).embeddings   # all cache hits
        np.testing.assert_array_equal(cold, cached)
        np.testing.assert_array_equal(cold, emb0)
        assert eng.nbr_cache.hits > 0
        s = eng.scheduler.stats
        assert s.cache_hits == 24 and s.cache_misses == 24
        eng.close()

    def test_invalidate_forces_recompute(self, graph, cfg, baseline):
        ref, _ = baseline
        eng = self._engine(graph, cfg, ref.params, nbr_cache="lru")
        t = np.arange(8)
        a = eng.infer(t, overlap=False).embeddings
        misses0 = eng.nbr_cache.misses
        dropped = eng.invalidate(t)               # every entry has its
        assert dropped == 8                       # target in its own list
        b = eng.infer(t, overlap=False).embeddings
        assert eng.nbr_cache.misses == misses0 + 8   # recomputed
        np.testing.assert_array_equal(a, b)       # same graph -> same PPR
        eng.close()

    def test_resident_store_matches_dense(self, graph, cfg, baseline):
        ref, emb0 = baseline
        eng = self._engine(graph, cfg, ref.params, features="resident")
        emb = eng.infer(np.arange(24), overlap=False).embeddings
        np.testing.assert_allclose(emb, emb0, rtol=1e-6, atol=1e-6)
        eng.close()

    def test_resident_transfer_savings_at_least_4x(self, graph, cfg,
                                                   baseline):
        """Acceptance: full-resident store ships >= 4x fewer bytes than
        the dense baseline per batch."""
        ref, _ = baseline
        eng = self._engine(graph, cfg, ref.params, features="resident")
        eng.infer(np.arange(16), overlap=False)
        s = eng.scheduler.stats
        assert s.bytes_dense >= 4 * s.bytes_shipped
        rep = eng.store_report()
        assert rep["features"]["resident_fraction"] == 1.0
        assert rep["features"]["miss_rows_shipped"] == 0
        eng.close()

    def test_partial_residency_miss_path(self, graph, cfg, baseline):
        """HBM budget below the matrix: cold rows ship via the host
        fallback partition, embeddings still match the dense engine."""
        ref, emb0 = baseline
        budget = 64 * (graph.feature_dim * 4)     # ~64 resident rows
        eng = self._engine(graph, cfg, ref.params, features="resident",
                           hbm_budget_bytes=budget)
        emb = eng.infer(np.arange(24), overlap=False).embeddings
        np.testing.assert_allclose(emb, emb0, rtol=1e-6, atol=1e-6)
        rep = eng.store_report()["features"]
        assert 0 < rep["resident_fraction"] < 1.0
        assert rep["miss_rows_shipped"] > 0
        eng.close()

    def test_invalidate_refreshes_resident_rows(self, graph, cfg):
        """Feature half of the graph-update hook: mutate graph.features,
        invalidate, and the resident table must serve the new rows."""
        import copy
        g = copy.deepcopy(graph)              # don't mutate the fixture
        eng = DecoupledEngine(g, cfg, batch_size=8,
                              store=StorePolicy(features="resident",
                                                nbr_cache="lru"))
        t = np.arange(8)
        before = eng.infer(t, overlap=False).embeddings
        g.features[:8] += 1.0                 # feature update at targets
        eng.invalidate(np.arange(8))
        after = eng.infer(t, overlap=False).embeddings
        assert np.abs(after - before).max() > 0
        # fresh engine over the updated graph agrees -> rows were truly
        # re-uploaded, not recomputed from a stale table
        ref = DecoupledEngine(g, cfg, params=eng.params, batch_size=8)
        np.testing.assert_allclose(
            after, ref.infer(t, overlap=False).embeddings,
            rtol=1e-6, atol=1e-6)
        ref.close()
        eng.close()

    def test_packed_strategy_matches_dense(self, graph, cfg, baseline):
        ref, emb0 = baseline
        eng = self._engine(graph, cfg, ref.params, features="packed")
        emb = eng.infer(np.arange(24), overlap=False).embeddings
        np.testing.assert_array_equal(emb, emb0)
        assert eng.last_dedup_ratio is not None
        assert eng.scheduler.stats.last_dedup_ratio == \
            eng.last_dedup_ratio
        eng.close()

    def test_hit_rate_at_zipf_steady_state(self, graph, cfg, baseline):
        """Acceptance: >= 80% neighborhood-cache hit rate under Zipf(1.1)
        once the stream has covered the popularity head."""
        ref, _ = baseline
        from repro.graphs.synthetic import zipf_traffic
        eng = self._engine(graph, cfg, ref.params, nbr_cache="lru",
                           nbr_capacity=512)
        targets = zipf_traffic(graph, 640, a=1.1, seed=0)
        eng.infer(targets[:256], overlap=False)   # warm to steady state
        s = eng.scheduler.stats
        h0, m0 = s.cache_hits, s.cache_misses
        eng.infer(targets[256:], overlap=False)
        hits, misses = s.cache_hits - h0, s.cache_misses - m0
        assert hits / (hits + misses) >= 0.80
        eng.close()


class TestPartialResidencyStore:
    def test_budget_zero_keeps_all_host_side(self, graph):
        st = DeviceFeatureStore(graph, f_pad=graph.feature_dim,
                                budget_bytes=0)
        assert st.num_resident == 0
        payload, _ = st.host_payload([np.array([0, 1])], 4)
        assert payload["miss_feats"].shape[0] == 2
        np.testing.assert_array_equal(payload["miss_feats"][0],
                                      graph.features[0])

    def test_miss_block_ships_at_f_in_not_f_pad(self, graph):
        """Per-batch transfer accounting counts ONLY the miss rows: the
        miss block crosses the link at f_in and is padded to the
        resident table's f_pad on the device, so bytes_shipped never
        charges MXU pad columns (resident-table layout) to the batch."""
        f_in = graph.feature_dim                  # 500
        st = DeviceFeatureStore(graph, f_pad=512,
                                budget_bytes=8 * 512 * 4)
        nls = ini_batch(graph, [0, 1], 16, num_threads=1)
        payload, _ = st.host_payload(nls, 16)
        assert payload["miss_feats"].shape[1] == f_in
        feats = np.asarray(st.device_feats(payload))
        assert feats.shape == (2, 16, 512)        # padded device-side
        np.testing.assert_array_equal(feats[0, 0, :f_in],
                                      graph.features[nls[0][0]])
        np.testing.assert_array_equal(feats[..., f_in:], 0.0)

    def test_hot_rows_selected_by_score(self, graph):
        score = np.zeros(graph.num_vertices)
        score[[3, 7]] = 1.0
        st = DeviceFeatureStore(graph, f_pad=graph.feature_dim,
                                budget_bytes=3 * graph.feature_dim * 4,
                                hot_scores=score)
        assert st.num_resident == 2
        assert st.slot_of[3] > 0 and st.slot_of[7] > 0


class TestServerReport:
    def test_report_surfaces_store_stats(self, graph, cfg):
        from repro.serve.gnn_server import GNNServer
        eng = DecoupledEngine(graph, cfg, batch_size=4,
                              store=StorePolicy(features="resident",
                                                nbr_cache="lru"))
        srv = GNNServer(eng, max_wait_s=0.005)
        srv.start()
        reqs = [srv.submit(int(t)) for t in [0, 1, 2, 3, 0, 1, 2, 3]]
        srv.drain(reqs, timeout=120)
        srv.stop()
        m = srv.report()["models"]["default"]
        for key in ("bytes_shipped", "transfer_ratio", "cache_hit_rate",
                    "dedup_ratio", "features", "nbr_cache"):
            assert key in m["store"]
        assert m["store"]["bytes_shipped"] > 0
        # resident store ships indices, not rows
        assert m["store"]["transfer_ratio"] < 0.5
        assert m["store"]["features"]["strategy"] == "resident"
        assert m["store"]["nbr_cache"]["capacity"] == 4096
        eng.close()
