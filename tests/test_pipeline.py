"""Staged host pipeline (BatchPlan IR): bitwise equivalence against the
pre-refactor monolithic prepare() (reconstructed here exactly as the old
engine composed it — per-batch einsum edge extras included), subgraph-row
cache semantics, frontier-exact dual invalidation, automatic repin
triggers, and the SGC lowering."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.batchplan import BatchPlan
from repro.core.engine import DecoupledEngine
from repro.core.ini import ini_batch
from repro.core.scheduler import PipelineScheduler
from repro.core.subgraph import batch_from_node_lists, build_batch
from repro.gnn.model import GNNConfig, init_gnn
from repro.graphs.synthetic import get_graph, zipf_traffic
from repro.serve.gnn_server import GNNServer
from repro.store import StorePolicy, SubgraphRowCache

KINDS = ("gcn", "sage", "gat", "appnp")
N = 16
C = 4
TARGETS = np.arange(8)


@pytest.fixture(scope="module")
def graph():
    return get_graph("flickr", scale=0.02, seed=1)   # ~1.8k vertices


def _cfg(kind, graph, n_layers=2):
    return GNNConfig(kind=kind, n_layers=n_layers, receptive_field=N,
                     f_in=graph.feature_dim)


def legacy_prepare(eng, targets):
    """The PRE-REFACTOR monolithic prepare(), reconstructed: one blob of
    INI + induced-subgraph build + feature payload, with the sg-mode edge
    extras recovered per batch by densifying adj (the old einsum path)."""
    cfg = eng.cfg
    n = cfg.receptive_field
    node_lists = ini_batch(eng.graph, [int(t) for t in targets], n,
                           cfg.ppr_alpha, cfg.ppr_eps, num_threads=1)
    src = eng._fsource
    sb = batch_from_node_lists(eng.graph, targets, node_lists, n,
                               eng.e_pad,
                               build_feats=src.needs_host_feats)
    d = {"mask": sb.mask}
    for k in eng.adj_keys:
        d[k] = sb.adj if k == "adj" else sb.adj_mean
    if eng.needs_edges:
        self_w = sb.adj[:, np.arange(sb.n), np.arange(sb.n)]
        indeg = np.einsum("cij->ci", (sb.adj_mean > 0).astype(np.float32))
        d.update(edge_src=sb.edge_src, edge_dst=sb.edge_dst,
                 edge_w=sb.edge_w, self_w=self_w.astype(np.float32))
        valid = sb.edge_w != 0
        dst_deg = np.take_along_axis(
            np.maximum(indeg, 1.0), sb.edge_dst.astype(np.int64), axis=1)
        d["edge_w_mean"] = np.where(valid, 1.0 / dst_deg, 0.0
                                    ).astype(np.float32)
    payload, _ = src.host_payload(
        node_lists, n, sb.feats if src.needs_host_feats else None)
    d.update(payload)
    return d


class TestStagedEqualsMonolithic:
    @pytest.mark.parametrize("impl", ("xla", "pallas"))
    @pytest.mark.parametrize("kind", KINDS)
    def test_bitwise_equal_embeddings(self, graph, kind, impl):
        """Acceptance: the staged pipeline (the default submit_chunk /
        infer path) produces bitwise-identical embeddings to the
        pre-refactor monolithic prepare() for every kind x impl.
        mode="sg" forces the edge arrays (and their carried extras) into
        the datapath, so the CSR-direct self_w/edge_w_mean are covered."""
        cfg = _cfg(kind, graph)
        params = init_gnn(cfg, jax.random.PRNGKey(2))
        with DecoupledEngine(graph, cfg, params=params, batch_size=C,
                             impl=impl, mode="sg", num_threads=1) as eng:
            staged = np.asarray(eng.submit_chunk(TARGETS[:C]).result())
            legacy = np.asarray(
                eng.run_device(legacy_prepare(eng, TARGETS[:C])))
            np.testing.assert_array_equal(staged, legacy)

    def test_dense_auto_mode_equal(self, graph):
        cfg = _cfg("gcn", graph)
        with DecoupledEngine(graph, cfg, batch_size=C, seed=3,
                             num_threads=1) as eng:
            staged = eng.infer(TARGETS, overlap=True).embeddings
            legacy = np.concatenate(
                [np.asarray(eng.run_device(legacy_prepare(eng, chunk)))
                 for chunk in (TARGETS[:C], TARGETS[C:])])
            np.testing.assert_array_equal(staged, legacy)

    def test_host_fn_spelling_still_pipelines(self, graph):
        """The one-stage back-compat spelling: a PipelineScheduler built
        from a plain host_fn behaves like before and reports its host
        time under the "host" stage label."""
        cfg = _cfg("gcn", graph)
        with DecoupledEngine(graph, cfg, batch_size=C, seed=3,
                             num_threads=1) as eng:
            staged = eng.infer(TARGETS, overlap=True).embeddings
            mono = PipelineScheduler(eng.prepare, eng.run_device, depth=2)
            outs, stats = mono.run([TARGETS[:C], TARGETS[C:]])
            mono.close()
            np.testing.assert_array_equal(
                staged, np.concatenate([np.asarray(o) for o in outs]))
            assert list(stats.stage_times) == ["host"]

    def test_stage_times_reported(self, graph):
        cfg = _cfg("gcn", graph)
        with DecoupledEngine(graph, cfg, batch_size=C, seed=3,
                             num_threads=1) as eng:
            eng.infer(TARGETS, overlap=True)
            s = eng.scheduler.stats.summary()
            times = s["stages"]["times"]
            assert set(times) == {"select", "build", "pack"}
            assert all(v > 0 for v in times.values())
            assert "build_hit_rate" in s["stages"]
            # per-stage sums make up the recorded host time
            assert sum(times.values()) == pytest.approx(
                s["latency"]["t_host"], rel=0.05)

    def test_plan_artifact_fields(self, graph):
        """plan() exposes the full BatchPlan: every stage's output is
        inspectable (the host-side mirror of InferenceResult.decision)."""
        cfg = _cfg("gcn", graph)
        pol = StorePolicy(nbr_cache="lru", nbr_capacity=32)
        with DecoupledEngine(graph, cfg, batch_size=C, seed=3,
                             store=pol, num_threads=1) as eng:
            plan = eng.plan(TARGETS[:C])
            assert isinstance(plan, BatchPlan)
            assert len(plan.node_lists) == C
            assert len(plan.rows) == C
            assert plan.rows[0].adj.shape == (N, N)
            assert plan.sb.batch_size == C
            assert plan.device is not None
            assert plan.nbr_misses == C    # cold cache
            # frontiers cached for exact invalidation
            assert all(f is not None for f in plan.frontiers.values())


class TestSubgraphRowCache:
    def _engine(self, graph, **pol):
        cfg = _cfg("gcn", graph)
        return DecoupledEngine(graph, cfg, batch_size=C, seed=4,
                               num_threads=1,
                               store=StorePolicy(nbr_cache="lru",
                                                 nbr_capacity=64, **pol))

    def test_hit_batch_identical_to_cold_build(self, graph):
        """Acceptance: a subgraph-row-cache hit batch is bitwise-identical
        to the cold build, and the Build stage was actually skipped."""
        eng = self._engine(graph)
        cold = eng.infer(TARGETS, overlap=False).embeddings
        assert eng.sg_cache.misses == len(TARGETS)
        hot = eng.infer(TARGETS, overlap=False).embeddings
        np.testing.assert_array_equal(cold, hot)
        assert eng.sg_cache.hits == len(TARGETS)
        s = eng.scheduler.stats
        assert s.build_hits == len(TARGETS)
        assert s.build_hit_rate == 0.5
        eng.close()

    def test_auto_follows_nbr_cache(self, graph):
        eng = self._engine(graph)                     # auto -> on
        assert eng.sg_cache is not None
        eng.close()
        eng = self._engine(graph, subgraph_rows="off")
        assert eng.sg_cache is None
        emb = eng.infer(TARGETS[:C], overlap=False).embeddings
        assert emb.shape == (C, eng.cfg.f_hidden)
        eng.close()
        cfg = _cfg("gcn", graph)
        eng = DecoupledEngine(graph, cfg, batch_size=C, num_threads=1)
        assert eng.sg_cache is None                   # no nbr cache
        eng.close()

    def test_rows_on_without_nbr_cache(self, graph):
        """subgraph_rows="on" alone still skips Build (the node list is
        deterministic in the key even when Select recomputes it)."""
        cfg = _cfg("gcn", graph)
        eng = DecoupledEngine(graph, cfg, batch_size=C, seed=4,
                              num_threads=1,
                              store=StorePolicy(subgraph_rows="on"))
        a = eng.infer(TARGETS[:C], overlap=False).embeddings
        b = eng.infer(TARGETS[:C], overlap=False).embeddings
        np.testing.assert_array_equal(a, b)
        assert eng.sg_cache.hits == C
        eng.close()

    def test_invalidate_drops_both_levels(self, graph):
        """Acceptance: invalidate() drops BOTH the neighborhood entry and
        the subgraph-row entry (frontier-exact on both)."""
        eng = self._engine(graph)
        eng.infer(TARGETS, overlap=False)
        assert len(eng.nbr_cache) == len(TARGETS)
        assert len(eng.sg_cache) == len(TARGETS)
        dropped = eng.invalidate(TARGETS)     # every push touches its
        assert dropped == len(TARGETS)        # own target
        assert len(eng.nbr_cache) == 0
        assert len(eng.sg_cache) == 0
        assert eng.sg_cache.invalidations == len(TARGETS)
        rep = eng.store_report()
        assert rep["subgraph_cache"]["invalidations"] == len(TARGETS)
        eng.close()

    def test_graph_update_recompute_matches_fresh_engine(self, graph):
        """Edge updates flow through both cache levels: post-update
        inference equals a fresh engine over the updated graph."""
        import copy
        g = copy.deepcopy(graph)
        cfg = _cfg("gcn", g)
        eng = DecoupledEngine(g, cfg, batch_size=C, seed=4, num_threads=1,
                              store=StorePolicy(nbr_cache="lru",
                                                nbr_capacity=64))
        eng.infer(TARGETS, overlap=False)              # warm both caches
        deg = g.degrees
        hubs = np.argsort(-deg)[:2]
        g.apply_edge_updates(insert=[(int(TARGETS[0]), int(hubs[0])),
                                     (int(hubs[1]), int(TARGETS[1]))])
        after = eng.infer(TARGETS, overlap=False).embeddings
        fresh = DecoupledEngine(g, cfg, params=eng.params, batch_size=C,
                                num_threads=1)
        np.testing.assert_array_equal(
            after, fresh.infer(TARGETS, overlap=False).embeddings)
        fresh.close()
        eng.close()

    def test_put_dropped_across_invalidate_generation(self):
        """A row built before an invalidate() must not land (same
        generation contract as the neighborhood cache)."""
        from repro.core.subgraph import build_subgraph_rows
        g = get_graph("flickr", scale=0.01, seed=0)
        cache = SubgraphRowCache(capacity=8)
        rows = build_subgraph_rows(g, np.arange(4), 8, 16)
        gen = cache.generation
        cache.invalidate([1])                 # update lands mid-build
        cache.put(("k",), rows, generation=gen,
                  frontier=np.arange(4))
        assert ("k",) not in cache
        cache.put(("k",), rows, generation=cache.generation,
                  frontier=np.arange(4))
        assert ("k",) in cache
        assert cache.get(("k",)).adj.flags.writeable is False


class TestAutoRepin:
    def _stream(self, eng, chunks):
        return [np.asarray(eng.submit_chunk(c).result()) for c in chunks]

    def test_fires_every_k_batches(self, graph):
        """Acceptance: repin_every=K fires on the pipeline's completion
        path at exactly floor(batches / K) times, and never corrupts an
        in-flight batch (outputs bitwise-equal to a no-repin engine)."""
        cfg = _cfg("gcn", graph)
        params = init_gnn(cfg, jax.random.PRNGKey(5))
        budget = 48 * graph.feature_dim * 4
        pol = StorePolicy(features="resident", hbm_budget_bytes=budget,
                          nbr_cache="lru", repin_every=3)
        traffic = zipf_traffic(graph, 40, a=1.1, seed=3)
        chunks = [traffic[i:i + C] for i in range(0, 40, C)]
        eng = DecoupledEngine(graph, cfg, params=params, batch_size=C,
                              store=pol, num_threads=1)
        outs = self._stream(eng, chunks)
        eng.scheduler.flush()
        eng.drain_repins()           # rebalances run on their own worker
        assert eng.auto_repins == len(chunks) // 3
        assert eng._fsource.repins == eng.auto_repins
        assert eng.store_report()["auto_repins"] == eng.auto_repins
        # same store strategy WITHOUT the trigger: outputs must match
        # bitwise — residency generation changes never touch the values
        ref = DecoupledEngine(
            graph, cfg, params=params, batch_size=C, num_threads=1,
            store=StorePolicy(features="resident",
                              hbm_budget_bytes=budget, nbr_cache="lru"))
        ref_outs = self._stream(ref, chunks)
        for a, b in zip(outs, ref_outs):
            np.testing.assert_array_equal(a, b)
        ref.close()
        eng.close()

    def test_hit_floor_trigger(self, graph):
        """repin_hit_floor: a resident hit rate below the floor triggers
        a repin without a batch-count schedule."""
        cfg = _cfg("gcn", graph)
        budget = 16 * graph.feature_dim * 4   # tiny: most lookups miss
        pol = StorePolicy(features="resident", hbm_budget_bytes=budget,
                          repin_hit_floor=1.0)
        eng = DecoupledEngine(graph, cfg, batch_size=C, seed=5,
                              store=pol, num_threads=1)
        eng.infer(TARGETS, overlap=False)      # serial path fires it too
        assert eng.auto_repins >= 1
        eng.drain_repins()
        assert eng._fsource.repins == eng.auto_repins
        # a floor that can never be met backs off instead of rebuilding
        # the table every batch
        assert eng._floor_wait > 1
        eng.close()

    def test_repin_promotes_observed_mass(self, graph):
        """Single-device PPR-mass feedback: after skewed traffic, repin
        residency covers the observed rows better than the degree prior
        (hit rate does not regress), bitwise-equal embeddings."""
        cfg = _cfg("gcn", graph)
        params = init_gnn(cfg, jax.random.PRNGKey(6))
        budget = 64 * graph.feature_dim * 4
        pol = StorePolicy(features="resident", hbm_budget_bytes=budget,
                          nbr_cache="lru")
        eng = DecoupledEngine(graph, cfg, params=params, batch_size=C,
                              store=pol, num_threads=1)
        traffic = zipf_traffic(graph, 64, a=1.1, seed=4)
        emb0 = eng.infer(traffic[:32], overlap=False).embeddings
        st = eng._fsource
        lk0, res0 = st.lookups, st.resident_lookups
        rep = eng.repin()
        assert rep["resident_rows"] > 0 and "mass_covered" in rep
        emb1 = eng.infer(traffic[:32], overlap=False).embeddings
        np.testing.assert_array_equal(emb0, emb1)  # residency-invariant
        after = (st.resident_lookups - res0) / (st.lookups - lk0)
        assert after >= (res0 / lk0) - 1e-9
        eng.close()

    def test_policy_validation(self):
        with pytest.raises(ValueError, match="repin"):
            StorePolicy(repin_every=4)                 # dense: no repin
        with pytest.raises(ValueError, match="repin_hit_floor"):
            StorePolicy(features="resident", repin_hit_floor=1.5)
        with pytest.raises(ValueError, match="subgraph_rows"):
            StorePolicy(subgraph_rows="maybe")
        pol = StorePolicy(features="resident", repin_every=8,
                          nbr_cache="lru")
        assert pol.describe()["repin_every"] == 8
        assert pol.cache_subgraph_rows is True

    def test_inflight_snapshot_survives_repin(self, graph):
        """A payload prepared before repin() gathers against ITS residency
        generation, not the new one (single-device mirror of the sharded
        snapshot test)."""
        cfg = _cfg("gcn", graph)
        budget = 48 * graph.feature_dim * 4
        pol = StorePolicy(features="resident", hbm_budget_bytes=budget,
                          nbr_cache="lru")
        eng = DecoupledEngine(graph, cfg, batch_size=8, seed=7,
                              store=pol, num_threads=1)
        node_lists, _, _ = eng._node_lists([int(t) for t in TARGETS])
        payload, _ = eng._fsource.host_payload(node_lists, N)  # in flight
        eng.infer(zipf_traffic(graph, 32, a=1.2, seed=5), overlap=False)
        for _ in range(3):
            eng.repin()                        # several generations later
        # the held payload gathers against ITS generation; a fresh
        # payload (new slots, new generation) must yield the same rows
        stale = np.asarray(eng._fsource.device_feats(payload))
        fresh_payload, _ = eng._fsource.host_payload(node_lists, N)
        fresh = np.asarray(eng._fsource.device_feats(fresh_payload))
        np.testing.assert_array_equal(stale, fresh)
        eng.close()


class TestSGCLowering:
    def test_matches_explicit_recurrence(self, graph):
        """sgc = K propagation steps + one linear map: the program output
        equals the explicit S^K X W recurrence (f64 reference, and
        bitwise against the same-order jnp recurrence)."""
        cfg = GNNConfig(kind="sgc", n_layers=3, receptive_field=N,
                        f_in=graph.feature_dim)     # K = 2 propagations
        params = init_gnn(cfg, jax.random.PRNGKey(8))
        with DecoupledEngine(graph, cfg, params=params, batch_size=C,
                             mode="dense", num_threads=1) as eng:
            emb = eng.infer(TARGETS[:C], overlap=False).embeddings
        sb = build_batch(graph, TARGETS[:C], N, e_pad=N * (N - 1),
                         num_threads=1)
        w = np.asarray(params["layer0"]["w"], np.float64)
        z = (sb.feats.astype(np.float64) @ w) * sb.mask[..., None]
        for _ in range(cfg.n_layers - 1):
            z = np.einsum("cij,cjf->cif", sb.adj.astype(np.float64), z)
        ref64 = np.where(sb.mask[..., None] > 0, z, -1e30).max(axis=1)
        np.testing.assert_allclose(emb, ref64, rtol=1e-4, atol=1e-5)
        # bitwise against the identical-op jnp recurrence
        zj = jnp.einsum("cnf,fg->cng", jnp.asarray(sb.feats),
                        jnp.asarray(params["layer0"]["w"]),
                        preferred_element_type=jnp.float32)
        zj = zj * sb.mask[..., None]
        for _ in range(cfg.n_layers - 1):
            zj = jnp.einsum("cij,cjf->cif", jnp.asarray(sb.adj), zj,
                            preferred_element_type=jnp.float32)
        refj = jnp.max(jnp.where(sb.mask[..., None] > 0, zj, -1e30),
                       axis=1)
        np.testing.assert_array_equal(emb, np.asarray(refj))

    def test_sgc_sg_mode_matches_dense(self, graph):
        cfg = GNNConfig(kind="sgc", n_layers=3, receptive_field=N,
                        f_in=graph.feature_dim)
        params = init_gnn(cfg, jax.random.PRNGKey(9))
        embs = {}
        for mode in ("dense", "sg"):
            with DecoupledEngine(graph, cfg, params=params, batch_size=C,
                                 mode=mode, num_threads=1,
                                 e_pad=N * (N - 1)) as eng:
                embs[mode] = eng.infer(TARGETS[:C],
                                       overlap=False).embeddings
        np.testing.assert_allclose(embs["dense"], embs["sg"],
                                   rtol=1e-5, atol=1e-5)

    def test_served_under_shared_dse_plan(self, graph):
        """sgc admits next to gcn under ONE explored DSEPlan and serves
        correct embeddings through the staged pipeline."""
        cfg_g = _cfg("gcn", graph)
        cfg_s = GNNConfig(kind="sgc", n_layers=3, receptive_field=N,
                          f_in=graph.feature_dim)
        e_g = DecoupledEngine(graph, cfg_g, batch_size=C, seed=10,
                              num_threads=1)
        e_s = DecoupledEngine(graph, cfg_s, batch_size=C, seed=11,
                              num_threads=1)
        standalone = e_s.infer(TARGETS[:C], overlap=False).embeddings
        srv = GNNServer(max_wait_s=0.01)
        srv.register("gcn", e_g).register("sgc", e_s)
        srv.start()
        try:
            reqs = [srv.submit(int(t), model) for t in TARGETS[:C]
                    for model in ("gcn", "sgc")]
            srv.drain(reqs, timeout=60)
            got = {(r.model, r.target): r.embedding for r in reqs}
            for i, t in enumerate(TARGETS[:C]):
                np.testing.assert_array_equal(got[("sgc", int(t))],
                                              standalone[i])
            rep = srv.report()
            assert rep["models"]["sgc"]["kind"] == "sgc"
            assert "times" in rep["models"]["sgc"]["stages"]
        finally:
            srv.stop()
            e_g.close()
            e_s.close()


class TestPipelinedScheduling:
    def test_stages_overlap_across_batches(self):
        """Stage i of batch k runs concurrently with stage i+1 of batch
        k-1: with two stages that each sleep, two batches take ~3 slots
        pipelined, not 4 serial."""
        log = []
        lock = threading.Lock()

        class _St:
            def __init__(self, name):
                self.name = name
                self.workers = 1

            def run(self, v):
                import time as _t
                with lock:
                    log.append((self.name, v))
                _t.sleep(0.05)
                return v

            def close(self):
                pass

        s = PipelineScheduler([_St("a"), _St("b")],
                              lambda v: jnp.asarray(v), depth=2)
        t0 = [s.submit(i) for i in range(3)]
        outs = [t.result() for t in t0]
        assert [int(np.asarray(o)) for o in outs] == [0, 1, 2]
        st = s.stats
        assert set(st.stage_times) == {"a", "b"}
        # pipelined wall < serial sum of stage times (3 batches x 2
        # stages x 50ms serial = 300ms; pipelined ~200ms)
        assert st.t_wall < 0.9 * (st.stage_times["a"]
                                  + st.stage_times["b"])
        s.close()

    def test_stage_error_isolated_to_ticket(self):
        class _Boom:
            name = "boom"
            workers = 1

            def run(self, v):
                if v == 1:
                    raise ValueError("bad batch")
                return v

            def close(self):
                pass

        s = PipelineScheduler([_Boom()], lambda v: jnp.asarray(v),
                              depth=2)
        bad = s.submit(1)
        ok = s.submit(2)
        with pytest.raises(ValueError, match="bad batch"):
            bad.result(timeout=10)
        assert int(np.asarray(ok.result(timeout=10))) == 2
        s.close()
